"""Per-kernel validation: Pallas (interpret=True) + jnp fallbacks vs ref.py.

Every kernel is swept over shapes (incl. GQA group sizes, padding-forcing
lengths) and dtypes, asserting allclose against the pure-jnp oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quantize import quantize_pallas
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas


def _qkv(key, B, T, S, H, K, D, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, T, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, S, K, D), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, S, K, D), jnp.float32).astype(dtype)
    return q, k, v


ATTN_CASES = [
    # B, T, S, H, K, D, causal, window
    (2, 16, 16, 4, 4, 8, True, 0),        # MHA causal
    (1, 16, 16, 6, 2, 16, True, 0),       # GQA rep=3
    (2, 8, 24, 4, 1, 8, True, 0),         # MQA, suffix queries (prefill)
    (1, 16, 16, 4, 2, 8, False, 0),       # bidirectional (encoder)
    (1, 32, 32, 4, 4, 8, True, 8),        # local window
    (1, 20, 20, 2, 2, 8, True, 0),        # non-multiple-of-block lengths
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_chunked_vs_ref(case, dtype):
    B, T, S, H, K, D, causal, window = case
    q, k, v = _qkv(jax.random.PRNGKey(0), B, T, S, H, K, D, dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_chunk=8, kv_chunk=8)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_pallas_interpret_vs_ref(case):
    B, T, S, H, K, D, causal, window = case
    q, k, v = _qkv(jax.random.PRNGKey(1), B, T, S, H, K, D, jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=8, block_k=8, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_pallas_block_sweep():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 32, 32, 4, 2, 16, jnp.float32)
    want = ref.attention_ref(q, k, v, causal=True)
    for bq, bk in [(8, 8), (16, 8), (8, 16), (32, 32)]:
        got = flash_attention_pallas(q, k, v, causal=True,
                                     block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5,
                                   err_msg=f"block ({bq},{bk})")


SSM_CASES = [(1, 8, 4, 2), (2, 16, 8, 4), (1, 24, 6, 3)]  # B, T, I, N


@pytest.mark.parametrize("B,T,I,N", SSM_CASES)
@pytest.mark.parametrize("impl", ["chunked", "pallas"])
def test_ssm_scan_vs_ref(B, T, I, N, impl):
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    x = jax.random.normal(ks[0], (B, T, I))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, I)))
    A = -jnp.exp(jax.random.normal(ks[2], (I, N)))
    Bm = jax.random.normal(ks[3], (B, T, N))
    C = jax.random.normal(ks[4], (B, T, N))
    D = jax.random.normal(ks[5], (I,))
    if impl == "pallas":
        y, h = ssm_scan_pallas(x, dt, A, Bm, C, D)
    else:
        y, h = ops.ssm_scan(x, dt, A, Bm, C, D, impl="chunked", time_chunk=4)
    yr, hr = ref.ssm_scan_ref(x, dt, A, Bm, C, D)
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h, hr, atol=1e-4, rtol=1e-4)


def test_ssm_step_matches_scan():
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    B, T, I, N = 2, 6, 4, 3
    x = jax.random.normal(ks[0], (B, T, I))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, I)))
    A = -jnp.exp(jax.random.normal(ks[2], (I, N)))
    Bm = jax.random.normal(ks[3], (B, T, N))
    C = jax.random.normal(ks[4], (B, T, N))
    D = jax.random.normal(ks[5], (I,))
    y_ref, h_ref = ref.ssm_scan_ref(x, dt, A, Bm, C, D)
    h = jnp.zeros((B, I, N))
    ys = []
    for t in range(T):
        y, h = ops.ssm_step(x[:, t], dt[:, t], A, Bm[:, t], C[:, t], D, h)
        ys.append(y)
    np.testing.assert_allclose(jnp.stack(ys, 1), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h, h_ref, atol=1e-4, rtol=1e-4)


RGLRU_CASES = [(1, 8, 4), (2, 16, 8), (1, 13, 6)]  # B, T, L


@pytest.mark.parametrize("B,T,L", RGLRU_CASES)
@pytest.mark.parametrize("impl", ["assoc", "pallas"])
def test_rglru_vs_ref(B, T, L, impl):
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    x = jax.random.normal(ks[0], (B, T, L))
    a = jax.random.normal(ks[1], (B, T, L))
    i = jax.random.normal(ks[2], (B, T, L))
    lam = jax.random.normal(ks[3], (L,))
    if impl == "pallas":
        hs, hT = rglru_pallas(x, a, i, lam)
    else:
        hs, hT = ops.rglru(x, a, i, lam, impl="assoc")
    hr, hTr = ref.rglru_ref(x, a, i, lam)
    np.testing.assert_allclose(hs, hr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(hT, hTr, atol=1e-4, rtol=1e-4)


def test_rglru_step_matches_scan():
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    B, T, L = 2, 5, 4
    x = jax.random.normal(ks[0], (B, T, L))
    a = jax.random.normal(ks[1], (B, T, L))
    i = jax.random.normal(ks[2], (B, T, L))
    lam = jax.random.normal(ks[3], (L,))
    hs_ref, _ = ref.rglru_ref(x, a, i, lam)
    h = jnp.zeros((B, L))
    for t in range(T):
        _, h = ops.rglru_step(x[:, t], a[:, t], i[:, t], lam, h)
    np.testing.assert_allclose(h, hs_ref[:, -1], atol=1e-4, rtol=1e-4)


def test_rglru_h0_seeding():
    """Chunked decode continuation: h0-seeded scan == suffix of full scan."""
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    B, T, L = 1, 12, 4
    x = jax.random.normal(ks[0], (B, T, L))
    a = jax.random.normal(ks[1], (B, T, L))
    i = jax.random.normal(ks[2], (B, T, L))
    lam = jax.random.normal(ks[3], (L,))
    full, _ = ref.rglru_ref(x, a, i, lam)
    head, h_mid = ops.rglru(x[:, :7], a[:, :7], i[:, :7], lam)
    tail, _ = ops.rglru(x[:, 7:], a[:, 7:], i[:, 7:], lam, h0=h_mid)
    np.testing.assert_allclose(jnp.concatenate([head, tail], 1), full,
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", [(8, 16), (7, 33), (128, 256), (1, 5)])
def test_quantize_pallas_vs_ref(shape):
    x = jax.random.normal(jax.random.PRNGKey(8), shape) * 3.0
    qr, sr = ref.quantize_ref(x)
    qp, sp = quantize_pallas(x)
    np.testing.assert_allclose(sp, sr, atol=1e-6, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(qp), np.asarray(qr))
    back = ops.dequantize(qp, sp)
    assert float(jnp.max(jnp.abs(back - x))) <= float(sp.max()) + 1e-6


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(9), (32, 64))
    q, s = ops.quantize(x)
    err = ops.dequantize(q, s) - x
    # max error <= scale/2 per row (symmetric int8 rounding)
    assert np.all(np.abs(np.asarray(err)) <= np.asarray(s) * 0.5 + 1e-6)
