"""Batching invariance + flush-timing regression tests.

Invariance: any workload run with ``batch=N`` must return byte-identical
reads and an identical final owner-tree state as ``batch=0`` — batching
changes only how RPC traffic is timed, never what the metadata says.

Timing regression: a batched RPC is priced by the DES at its *flush*
position — never earlier than the issue point of its last coalesced
member (the pre-fix batcher back-dated the whole batch to the first
member's ledger slot, making batching optimistically free).
"""

import random

import pytest

from repro.core.basefs import BaseFS, EventKind
from repro.core.consistency import make_fs
from repro.core.costmodel import CostModel

PATHS = ("/inv/a", "/inv/b")


def _apply_script(fs, script):
    """Run a (client, op, path, offset, size) script on PosixFS; return reads."""
    layer = make_fs("posix", fs)
    handles = {}
    reads = []
    for client, op, path, offset, size in script:
        key = (client, path)
        if key not in handles:
            handles[key] = layer.open(client, path, node=client % 4)
        fh = handles[key]
        layer.seek(fh, offset)
        if op == "write":
            payload = bytes(((offset + i) * 31 + client) & 0xFF
                            for i in range(size))
            layer.write(fh, payload)
        else:
            reads.append(layer.read(fh, size))
    fs.drain()
    return reads


def _owner_state(fs):
    """Final server-side owner map, merged across shards, per path."""
    state = {}
    for path in PATHS:
        ivs = []
        for sh in fs.server.shards:
            tree = sh.trees.get(path)
            if tree is not None:
                ivs.extend((iv.start, iv.end, iv.value) for iv in tree)
        runs = []
        for s, e, v in sorted(ivs):
            if runs and runs[-1][1] == s and runs[-1][2] == v:
                runs[-1] = (runs[-1][0], e, v)
            else:
                runs.append((s, e, v))
        state[path] = runs
    return state


def _random_script(rng, n_ops=120, n_clients=4):
    script = []
    for _ in range(n_ops):
        client = rng.randrange(n_clients)
        path = rng.choice(PATHS)
        offset = rng.randrange(0, 4096)
        size = rng.randrange(1, 512)
        op = "write" if rng.random() < 0.6 else "read"
        script.append((client, op, path, offset, size))
    return script


def _check_invariance(script, batch, **kw):
    base = BaseFS(batch=0)
    batched = BaseFS(batch=batch, **kw)
    reads0 = _apply_script(base, script)
    reads1 = _apply_script(batched, script)
    assert reads0 == reads1, "batched reads diverge from batch=0"
    assert _owner_state(base) == _owner_state(batched), (
        "batched final owner trees diverge from batch=0"
    )


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("batch", (2, 4, 16))
def test_batched_runs_equal_unbatched(seed, batch):
    script = _random_script(random.Random(seed))
    _check_invariance(script, batch)


@pytest.mark.parametrize("seed", range(3))
def test_batched_sharded_runs_equal_unbatched(seed):
    script = _random_script(random.Random(1000 + seed))
    _check_invariance(script, 8, num_shards=4)


def test_batched_runs_equal_unbatched_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    op = st.tuples(
        st.integers(0, 3),
        st.sampled_from(["write", "read"]),
        st.sampled_from(list(PATHS)),
        st.integers(0, 2048),
        st.integers(1, 256),
    )

    @hypothesis.given(script=st.lists(op, min_size=1, max_size=60),
                      batch=st.integers(2, 16))
    @hypothesis.settings(deadline=None, max_examples=50)
    def run(script, batch):
        _check_invariance(script, batch)

    run()


# ---------------------------------------------------------------------------
# Flush-timing regression (the PR's tentpole bugfix).
# ---------------------------------------------------------------------------
def test_batched_rpc_not_priced_before_last_member():
    """A batched attach RPC starts at/after its last member's issue point.

    The posix streaming writer issues write -> attach(enqueue) four times
    per batch; each member's issue point is its SSD_WRITE event.  The
    flush RPC must (a) appear in the ledger after ALL member writes — the
    pre-fix batcher put it at the FIRST member's slot — and (b) be priced
    by the DES no earlier than the last member write completes.
    """
    fs = BaseFS(batch=4)
    pfs = make_fs("posix", fs)
    fh = pfs.open(0, "/f")
    for _ in range(12):
        pfs.write(fh, b"x" * 64)
    fs.drain()

    trace, ft = [], []
    CostModel().replay(fs.ledger, trace=trace, flush_trace=ft)
    times = {e.seq: (start, finish) for e, start, finish in trace}
    recs = {rec.event.seq: rec for rec in ft}

    member_writes = []
    checked = 0
    for e in fs.ledger.events:
        if e.kind is EventKind.SSD_WRITE:
            member_writes.append(e)
        elif e.kind is EventKind.RPC and e.rpc_type == "attach":
            assert e.rpc_calls == len(member_writes)
            # (a) ledger order: every member write precedes the flush.
            assert all(w.seq < e.seq for w in member_writes)
            # (b) DES pricing: no part of the batch departs before its
            # FIRST member, and the FINAL sub-batch — the one carrying
            # the last member (membership is time-split where the
            # window expired mid-batch) — departs no earlier than that
            # member's completion.
            rec = recs[e.seq]
            first_member_done = times[member_writes[0].seq][1]
            last_member_done = max(times[w.seq][1] for w in member_writes)
            assert times[e.seq][0] >= first_member_done
            assert rec.sends[-1] >= last_member_done
            member_writes = []
            checked += 1
    assert checked == 3  # 12 writes -> 4+4+4


def test_dependent_read_blocks_on_query_round_trip():
    """A read consuming a batched query's answer waits for the RPC."""
    fs = BaseFS(batch=8)
    cfs = make_fs("commit", fs)
    w = cfs.open(0, "/f", node=0)
    cfs.write(w, b"d" * 64)
    cfs.commit(w)
    r = cfs.open(1, "/f", node=1)
    cfs.seek(r, 0)
    assert cfs.read(r, 64) == b"d" * 64
    fs.drain()

    trace = []
    CostModel().replay(fs.ledger, trace=trace)
    reader = [(e, s, f) for e, s, f in trace if e.client == 1]
    assert [e.kind for e, _s, _f in reader] == [EventKind.RPC,
                                               EventKind.NET_TRANSFER]
    (q, _qs, q_done), (_n, n_start, _nf) = reader
    assert q.flush == "dep"
    # The transfer starts only after the query round trip completes.
    assert n_start >= q_done


def test_batching_costs_more_than_backdating_but_less_than_unbatched():
    """Honest flush pricing sits between 'free' and per-call RPCs."""
    def makespan(batch):
        fs = BaseFS(batch=batch)
        pfs = make_fs("posix", fs)
        fh = pfs.open(0, "/f")
        fs.ledger.mark_phase("w")
        for _ in range(64):
            pfs.write(fh, b"x" * 1024)
        fs.drain()
        return CostModel().phase(fs.ledger, "w").duration

    unbatched = makespan(0)
    batched = makespan(16)
    # Fewer round trips still win...
    assert batched < unbatched
    # ...but the flush penalty + round trips keep it nonzero-overhead
    # versus pure device time (the old model priced batches ~free).
    fs = BaseFS(batch=16)
    pfs = make_fs("posix", fs)
    fh = pfs.open(0, "/f")
    fs.ledger.mark_phase("w")
    for _ in range(64):
        pfs.write(fh, b"x" * 1024)
    fs.drain()
    rpc_time = sum(
        1 for e in fs.ledger.events if e.kind is EventKind.RPC
    )
    assert rpc_time == 4  # 64 coalesced 16-fold
