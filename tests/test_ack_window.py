"""Ack-windowed fire-and-forget attach flushes + timer-split membership.

PR-5 tentpole coverage:

* **PR-4 golden invariance** — ``ack_window=0`` with ``linger=0``
  replays event-for-event identical (ledger digest AND bitwise DES
  durations) to ledgers captured from the repository BEFORE the
  time-driven membership / ack-window changes, across all four
  consistency models;
* **fire-and-forget semantics** — with ``ack_window=K > 0`` a streaming
  writer's chain runs past its attach flushes and only stalls when K
  flushes are unacked or a sync point (fence, drain, dependent read)
  forces synchronization — fences on an EMPTY queue record a zero-cost
  sync marker so unacked flushes cannot leak past a commit;
* **monotonicity** — on the SAME realized schedule and split plan
  (forced-order counterfactual), increasing the ack window never
  increases any event's completion time (seeded + hypothesis);
* **timer-split determinism** — sub-batch split plans are a pure
  function of the seeded schedule: identical across replays, and
  replaying a recorded plan reproduces identical timing.
"""

import hashlib
import random

import pytest

from repro.core.basefs import RPC_FENCE_MARKER, BaseFS
from repro.core.consistency import make_fs
from repro.core.costmodel import CostModel
from repro.io.workloads import cc_r, run_workload

KB = 1024


# ---------------------------------------------------------------------------
# PR-4 golden invariance: ack_window=0 + linger=0 across all four models.
# ---------------------------------------------------------------------------
#: Captured from the repository at PR 4 (commit fcb3cca), before the
#: timer-split / ack-window changes: sha256 over the repr of the PR-4
#: event tuples, plus bitwise per-phase DES durations (float.hex), for
#: ``cc_r(2, 8KB, model, p=3, m=4)`` on ``BaseFS(num_shards=2, batch=8,
#: linger=0.0)``.
PR4_GOLDEN = {
    "posix": (
        "4cf3f2cff7b38771b2f22b2c27f77da35c00b521730d6f61befc5959c6a4aff1",
        [("write", "0x1.fb425610d8c0bp-12"),
         ("read", "0x1.1bdcb2fc74b90p-11")],
    ),
    "commit": (
        "8bdfd31ab5c33030c2bce5355b2bfc436ea8668c696ea8fa78b2f9642a315f2a",
        [("write", "0x1.b4bc70f03e528p-12"),
         ("read", "0x1.1bdcb2fc74b90p-11")],
    ),
    "session": (
        "d5ed41d99ce98a982a9a1ee73c5fab3e4fe58c0866c11ac805e31dd7f1f2b659",
        [("phase0", "0x1.47fe9c52b17dcp-13"),
         ("write", "0x1.b4bc70f03e526p-12"),
         ("read", "0x1.f21948b7900b0p-12")],
    ),
    "mpiio": (
        "a6e1d39671cd24033ae353fba8a9fbc4f6ace67958eb44dc4b381b8afef78043",
        [("write", "0x1.14fde8f97e30fp-11"),
         ("read", "0x1.f21948b7900b6p-12")],
    ),
}


def _pr4_event_tuples(ledger):
    """The PR-4 Event fields (``members`` postdates the capture)."""
    return [
        (e.kind.value, e.client, e.nbytes, e.rpc_type, e.peer, e.seq,
         e.rpc_ranges, e.shard, e.rpc_calls, e.flush, e.linger, e.deps,
         e.opened_after, e.last_after, e.forced_after)
        for e in ledger.events
    ]


@pytest.mark.parametrize("model", sorted(PR4_GOLDEN))
def test_ack0_linger0_matches_pr4_goldens(model):
    digest, phases = PR4_GOLDEN[model]
    cfg = cc_r(2, 8 * KB, model, p=3, m=4)
    fs = BaseFS(num_shards=2, batch=8, linger=0.0, ack_window=0)
    res = run_workload(cfg, fs=fs)
    got = hashlib.sha256(
        repr(_pr4_event_tuples(fs.ledger)).encode()
    ).hexdigest()
    assert got == digest, f"{model}: ledger diverged from the PR-4 capture"
    assert [(p.name, p.duration.hex()) for p in res.phases] == phases, (
        f"{model}: DES durations diverged from the PR-4 capture"
    )


@pytest.mark.parametrize("model", sorted(PR4_GOLDEN))
def test_ack_window_default_is_zero_and_bitwise_equal(model):
    # Omitting ack_window entirely == ack_window=0, bitwise: ledger,
    # per-event DES times and phase durations.
    cfg = cc_r(2, 8 * KB, model, p=3, m=4)
    traces, durations, tuples = [], [], []
    for kwargs in ({}, {"ack_window": 0}):
        fs = BaseFS(num_shards=2, batch=8, **kwargs)
        run_workload(cfg, fs=fs)
        tr = []
        phases = CostModel().replay(fs.ledger, trace=tr)
        traces.append([(e.seq, s, f) for e, s, f in tr])
        durations.append([(p.name, p.duration) for p in phases])
        tuples.append(_pr4_event_tuples(fs.ledger))
    assert tuples[0] == tuples[1]
    assert traces[0] == traces[1]
    assert durations[0] == durations[1]


# ---------------------------------------------------------------------------
# Fire-and-forget semantics.
# ---------------------------------------------------------------------------
def _stream_writer(ack_window, n_ops=16, batch=4, linger=0.0):
    """One posix client streaming small writes from the MEMORY burst
    buffer: at linger=0 every attach flushes as a singleton before the
    next write, and the sub-microsecond mem tier makes the RPC round
    trip the only thing that can hold the chain back — the config where
    blocking flushes hurt a streaming writer the most."""
    fs = BaseFS(batch=batch, linger=linger, ack_window=ack_window)
    pfs = make_fs("posix", fs)
    fh = pfs.open(0, "/stream", node=0, tier="mem")
    fs.ledger.mark_phase("write")
    for j in range(n_ops):
        pfs.seek(fh, j * 8 * KB)
        pfs.write(fh, b"w" * 8 * KB)
    fs.drain()
    return fs


def test_fire_and_forget_lets_writers_stream():
    durs, fts = {}, {}
    for k in (0, 4):
        fs = _stream_writer(ack_window=k)
        ft = []
        phases = CostModel().replay(fs.ledger, flush_trace=ft)
        durs[k] = next(p for p in phases if p.name == "write").duration
        fts[k] = ft
    # ack_window=0: every linger-reason flush blocks the chain.
    assert all(rec.blocking for rec in fts[0]
               if rec.event.flush == "linger")
    # ack_window=4: the same flushes are fire-and-forget and the write
    # phase gets strictly shorter — the chain streams past the RPCs.
    assert all(not rec.blocking for rec in fts[4]
               if rec.event.flush == "linger")
    assert durs[4] < durs[0]
    # The drain-close tail flush stays synchronous in both.
    assert all(rec.blocking for rec in fts[4]
               if rec.event.flush == "close")


def test_window_bound_stalls_at_k_unacked():
    # K=1 admits exactly one outstanding flush: the second flush in a
    # burst must wait for the first ack (ack_wait > 0 somewhere), while
    # a wide window absorbs the whole burst without stalling.
    stalls = {}
    for k in (1, 64):
        fs = _stream_writer(ack_window=k, n_ops=12)
        ft = []
        CostModel().replay(fs.ledger, flush_trace=ft)
        stalls[k] = sum(rec.ack_wait for rec in ft)
    assert stalls[1] > 0.0
    assert stalls[64] == 0.0
    assert stalls[64] < stalls[1]


def test_fence_on_empty_queue_records_sync_marker():
    # 8 writes at batch=4 -> both attach batches close on the SIZE cap,
    # so the file-close fence finds an empty queue.  With an ack window
    # the unacked flushes must not leak past the fence: a zero-cost
    # sync marker is recorded and the DES drains the window there.
    fs = BaseFS(batch=4, ack_window=2)
    pfs = make_fs("posix", fs)
    fh = pfs.open(0, "/fence", node=0)
    for _ in range(8):
        pfs.write(fh, b"x" * KB)
    pfs.close(fh)
    attaches = [e for e in fs.ledger.events if e.rpc_type == "attach"]
    markers = [e for e in fs.ledger.events
               if e.rpc_type == RPC_FENCE_MARKER]
    assert [e.flush for e in attaches] == ["size", "size"]
    assert len(markers) == 1
    assert markers[0].seq > attaches[-1].seq
    # The chain's clock at the marker covers every flush response.
    tr, ft = [], []
    CostModel().replay(fs.ledger, trace=tr, flush_trace=ft)
    marker_finish = next(f for e, _s, f in tr
                         if e.rpc_type == RPC_FENCE_MARKER)
    assert marker_finish >= max(rec.response for rec in ft)
    # Without an ack window the same run records no marker (golden
    # ledgers stay clean).
    fs0 = BaseFS(batch=4, ack_window=0)
    pfs0 = make_fs("posix", fs0)
    fh0 = pfs0.open(0, "/fence", node=0)
    for _ in range(8):
        pfs0.write(fh0, b"x" * KB)
    pfs0.close(fh0)
    assert not any(e.rpc_type == RPC_FENCE_MARKER
                   for e in fs0.ledger.events)


def test_dependent_read_synchronizes_consumer():
    # A reader's query flush stays blocking under any ack window (its
    # answer is consumed), and the producer's dep-forced attach flush is
    # fire-and-forget for the PRODUCER while the consumer still waits on
    # the Event.deps edge — the correctness backstop.
    fs = BaseFS(batch=16, ack_window=8)
    pfs = make_fs("posix", fs)
    w = pfs.open(0, "/f", node=0)
    pfs.write(w, b"live data!")
    r = pfs.open(1, "/f", node=1)
    assert pfs.read(r, 10) == b"live data!"
    fs.drain()
    ft = []
    CostModel().replay(fs.ledger, flush_trace=ft)
    attach = next(rec for rec in ft if rec.event.rpc_type == "attach")
    query = next(rec for rec in ft if rec.event.rpc_type == "query")
    assert attach.event.flush == "dep" and not attach.blocking
    assert query.blocking
    assert attach.event.seq in query.event.deps
    assert query.dep_wait > 0.0


# ---------------------------------------------------------------------------
# Monotonicity: a wider ack window never slows any event (forced order).
# ---------------------------------------------------------------------------
def _random_script(rng, n_ops=80, n_clients=4):
    return [(
        rng.randrange(n_clients),
        "write" if rng.random() < 0.7 else "read",
        rng.choice(("/s/a", "/s/b")),
        rng.randrange(0, 4096),
        rng.randrange(1, 512),
    ) for _ in range(n_ops)]


def _apply_script(fs, script):
    layer = make_fs("posix", fs)
    handles = {}
    for client, op, path, offset, size in script:
        key = (client, path)
        if key not in handles:
            handles[key] = layer.open(client, path, node=client % 3)
        fh = handles[key]
        layer.seek(fh, offset)
        if op == "write":
            layer.write(fh, bytes(
                ((offset + i) * 13 + client) & 0xFF for i in range(size)
            ))
        else:
            layer.read(fh, size)
    fs.drain()


def _ack_monotone_check(script, batch, shards, linger, k_lo, k_hi):
    # Build the ledger ONCE with an ack window enabled so fence markers
    # are present, then price the SAME schedule and split plan at both
    # windows: relaxing the window can only remove stalls (max-plus).
    fs = BaseFS(batch=batch, num_shards=shards, linger=linger,
                ack_window=max(1, k_lo))
    _apply_script(fs, script)
    cm = CostModel()
    order, splits, t_lo, t_hi = [], {}, [], []
    lo = cm.replay(fs.ledger, trace=t_lo, ack_window=k_lo,
                   record_order=order, record_splits=splits)
    hi = cm.replay(fs.ledger, trace=t_hi, ack_window=k_hi,
                   exec_order=order, exec_splits=splits)
    for (e1, _s1, f1), (e2, _s2, f2) in zip(t_lo, t_hi):
        assert e1.seq == e2.seq
        assert f2 <= f1 + 1e-15, (
            f"widening ack {k_lo}->{k_hi} slowed seq {e1.seq}"
        )
    assert sum(p.duration for p in hi) \
        <= sum(p.duration for p in lo) + 1e-15


@pytest.mark.parametrize("seed", range(6))
def test_wider_ack_window_never_slower_seeded(seed):
    rng = random.Random(seed)
    k_lo = rng.choice([0, 1, 2])
    _ack_monotone_check(_random_script(rng),
                        batch=rng.choice([2, 4, 8, 16]),
                        shards=rng.choice([1, 2, 4]),
                        linger=rng.choice([0.0, 20e-6, None]),
                        k_lo=k_lo, k_hi=k_lo + rng.choice([1, 4, 16]))


def test_wider_ack_window_never_slower_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    op = st.tuples(
        st.integers(0, 3),
        st.sampled_from(["write", "read"]),
        st.sampled_from(["/s/a", "/s/b"]),
        st.integers(0, 2048),
        st.integers(1, 256),
    )

    @hypothesis.given(
        script=st.lists(op, min_size=1, max_size=50),
        batch=st.integers(2, 16),
        shards=st.sampled_from([1, 2, 4]),
        linger=st.sampled_from([0.0, 20e-6, 50e-6]),
        k_lo=st.integers(0, 4),
        k_step=st.integers(1, 16),
    )
    @hypothesis.settings(deadline=None, max_examples=40)
    def run(script, batch, shards, linger, k_lo, k_step):
        _ack_monotone_check(script, batch, shards, linger,
                            k_lo, k_lo + k_step)

    run()


# ---------------------------------------------------------------------------
# Timer-split determinism under seeded schedules.
# ---------------------------------------------------------------------------
def _split_run(seed):
    fs = BaseFS(batch=16, num_shards=2, linger=30e-6)
    _apply_script(fs, _random_script(random.Random(seed), n_ops=100))
    return fs


@pytest.mark.parametrize("seed", range(3))
def test_timer_splits_deterministic(seed):
    plans, traces = [], []
    for _ in range(2):
        fs = _split_run(seed)
        splits, tr = {}, []
        CostModel().replay(fs.ledger, trace=tr, record_splits=splits)
        plans.append(splits)
        traces.append([(e.seq, s, f) for e, s, f in tr])
    assert plans[0] == plans[1]
    assert traces[0] == traces[1]


def test_recorded_split_plan_replays_identically():
    fs = _split_run(0)
    cm = CostModel()
    splits, order, t1 = {}, [], []
    cm.replay(fs.ledger, trace=t1, record_splits=splits,
              record_order=order)
    # The raced schedule must actually exercise re-splitting somewhere.
    assert any(b for b in splits.values()), "no timer split occurred"
    t2 = []
    cm.replay(fs.ledger, trace=t2, exec_splits=splits, exec_order=order)
    assert [(e.seq, s, f) for e, s, f in t1] \
        == [(e.seq, s, f) for e, s, f in t2]


def test_split_messages_counted_in_phase_result():
    fs = _split_run(1)
    splits = {}
    phases = CostModel().replay(fs.ledger, record_splits=splits)
    n_extra = sum(len(b) for b in splits.values())
    assert n_extra > 0
    total_events = sum(p.rpc_count for p in phases)
    total_msgs = sum(p.rpc_msgs for p in phases)
    assert total_msgs == total_events + n_extra
