"""Dry-run plumbing testable on one device: input_specs shapes per mode,
abstract state/cache construction, roofline artifact loading.
"""

import dataclasses
import glob
import os
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import tiny_config
from repro.launch import mesh as M
from repro.launch.dryrun import input_specs
from repro.models.config import ShapeCell

CFG = dataclasses.replace(tiny_config("qwen3-32b"), dtype=jnp.float32)


def test_input_specs_train():
    cell = ShapeCell("t", 32, 8, "train")
    (state, batch), kw = input_specs(CFG, cell)
    assert kw == {}
    assert batch["tokens"].shape == (8, 32)
    assert batch["labels"].dtype == jnp.int32
    assert set(state) == {"params", "opt", "step"}
    # no allocation happened: everything is abstract
    for leaf in jax.tree.leaves(state) + jax.tree.leaves(batch):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_input_specs_prefill_includes_modality():
    wcfg = dataclasses.replace(tiny_config("whisper-small"),
                               dtype=jnp.float32)
    cell = ShapeCell("p", 32, 4, "prefill")
    (params, batch), kw = input_specs(wcfg, cell)
    assert "frames" in batch
    assert batch["frames"].shape == (4, wcfg.enc_len, wcfg.d_model)
    for leaf in jax.tree.leaves(params):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_input_specs_decode_cache_shapes():
    cell = ShapeCell("d", 64, 4, "decode")
    (params, cache, tok, idx), kw = input_specs(CFG, cell)
    assert tok.shape == (4, 1)
    assert idx.shape == ()
    ks = [leaf for path, leaf in
          jax.tree_util.tree_flatten_with_path(cache)[0]]
    assert all(isinstance(leaf, jax.ShapeDtypeStruct) for leaf in ks)
    # attention KV caches carry the cell's max length
    shapes = {leaf.shape for leaf in ks}
    assert any(s[-3] == 64 or (len(s) > 3 and s[-3] == 64) for s in shapes)


def test_abstract_state_matches_init_shapes():
    from repro.train.train_step import train_state_init
    ab = M.abstract_state(CFG)
    real = train_state_init(jax.random.PRNGKey(0), CFG, M.opt_for(CFG))
    for a, r in zip(jax.tree.leaves(ab), jax.tree.leaves(real)):
        assert a.shape == r.shape and a.dtype == r.dtype


def test_roofline_rows_load_and_terms():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    import roofline
    if not glob.glob(os.path.join(roofline.ARTIFACT_DIR, "*.json")):
        pytest.skip("no dry-run artifacts present")
    rows = roofline.load_rows()
    ok = [r for r in rows if r["status"] == "ok"]
    assert ok, "expected compiled cells"
    for r in ok:
        assert r["dominant"] in ("compute", "memory", "collective")
        assert r["compute_s"] >= 0 and r["memory_s"] > 0
        assert 0 <= r["roofline_frac"] <= 1.5
    table = roofline.format_table(rows)
    assert "arch" in table.splitlines()[0]
