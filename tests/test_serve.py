"""Serving correctness: prefill + decode_step must reproduce forward().

For each model family: teacher-forced decode logits match the full
forward pass position by position (the KV/state cache is exact, not an
approximation) and prefill's last-position logits agree with forward.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import tiny_config
from repro.data.pipeline import synthetic_batch
from repro.models import transformer as T
from repro.serve.decode import generate

FAMILIES = ["starcoder2-3b", "qwen3-32b", "falcon-mamba-7b",
            "recurrentgemma-9b", "granite-moe-1b-a400m", "whisper-small"]


def _cfg(name):
    return dataclasses.replace(tiny_config(name), dtype=jnp.float32)


@pytest.mark.parametrize("name", FAMILIES)
def test_prefill_matches_forward(name):
    cfg = _cfg(name)
    B, S = 2, 12
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic_batch(jax.random.PRNGKey(1), cfg, B, S)
    extras = {k: batch[k] for k in ("frames", "patches") if k in batch}
    full, _ = T.forward(params, batch["tokens"], cfg, **extras)
    last, _cache = T.prefill(params, batch["tokens"], cfg, max_len=S + 4,
                             **extras)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("name", FAMILIES)
def test_decode_matches_forward_teacher_forced(name):
    if tiny_config(name).kind == "encdec":
        pytest.skip("cross-cache decode covered in test_generate_runs")
    cfg = _cfg(name)
    B, S, EXTRA = 1, 8, 4
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + EXTRA), 0,
                              cfg.vocab, jnp.int32)
    full, _ = T.forward(params, toks, cfg)
    _, cache = T.prefill(params, toks[:, :S], cfg, max_len=S + EXTRA)
    for i in range(EXTRA):
        logits, cache = T.decode_step(params, cache, toks[:, S + i:S + i + 1],
                                      jnp.int32(S + i), cfg)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, S + i]),
            atol=5e-4, rtol=5e-4, err_msg=f"{name} step {i}")


@pytest.mark.parametrize("name", ["recurrentgemma-9b"])
def test_local_window_ring_cache_long_decode(name):
    """Decode far past the window: ring cache must equal full forward."""
    cfg = _cfg(name)           # local_window=8 in the tiny config
    B, S = 1, 6
    total = 20                 # > 2x window -> the ring wraps
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, total), 0,
                              cfg.vocab, jnp.int32)
    full, _ = T.forward(params, toks, cfg)
    _, cache = T.prefill(params, toks[:, :S], cfg, max_len=total)
    for i in range(S, total):
        logits, cache = T.decode_step(params, cache, toks[:, i:i + 1],
                                      jnp.int32(i), cfg)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, i]),
            atol=1e-3, rtol=1e-3, err_msg=f"pos {i}")


def test_generate_runs_all_families():
    for name in FAMILIES:
        cfg = _cfg(name)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 5), 0,
                                    cfg.vocab, jnp.int32)
        extras = {}
        if cfg.frontend == "audio":
            from repro.models.frontends import audio_frames
            extras["frames"] = audio_frames(cfg, 1, key=jax.random.PRNGKey(4))
        out = generate(params, cfg, prompt, steps=4, **extras)
        assert out.shape == (1, 4)
        assert bool(jnp.all((out >= 0) & (out < cfg.vocab))), name
