"""Multi-device semantics, run in a SUBPROCESS with 8 forced host devices
(jax pins the device count at first init, so the main pytest process must
stay at 1 device for every other test).

Covers: MoE a2a == sort_scatter numerics, shard_tree constraint binding,
mesh construction, and a tiny end-to-end sharded train step.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.configs.registry import tiny_config
    from repro.models import moe as M
    from repro.models import transformer as T
    from repro.models.sharding import active_rules, rules_for
    from repro.launch.mesh import (batch_shardings, opt_for,
                                   state_shardings)
    from repro.models.config import ShapeCell
    from repro.data.pipeline import synthetic_batch
    from repro.train.train_step import make_train_step, train_state_init

    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = rules_for("tp", multi_pod=False)

    # ---- 1) a2a MoE == sort_scatter (no-drop capacity) -----------------
    cfg = dataclasses.replace(
        tiny_config("granite-moe-1b-a400m"), dtype=jnp.float32,
        moe_capacity=8.0, moe_impl="a2a")
    key = jax.random.PRNGKey(0)
    p = M.moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, cfg.d_model))
    y_ref, aux_ref = M._moe_local(
        x.reshape(-1, cfg.d_model), p, cfg,
        M.capacity(cfg, x.shape[0] * x.shape[1]))
    y_ref = y_ref.reshape(x.shape)

    with mesh, active_rules(rules, mesh):
        y_a2a, aux_a2a = jax.jit(
            lambda p, x: M.moe_forward(p, x, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    # aux is a per-shard Switch estimator under a2a (pmean of local
    # losses), not bit-equal to the global estimator; bound it instead.
    assert abs(float(aux_a2a) - float(aux_ref)) < 0.5, (aux_a2a, aux_ref)
    print("OK a2a==sort_scatter")

    # ---- 2) sharded train step == single-device train step -------------
    cfg2 = dataclasses.replace(tiny_config("qwen3-32b"), dtype=jnp.float32)
    cell = ShapeCell("t", 16, 8, "train")
    opt = opt_for(cfg2)
    state = train_state_init(jax.random.PRNGKey(0), cfg2, opt)
    batch = synthetic_batch(jax.random.PRNGKey(1), cfg2, 8, 16)
    step = make_train_step(cfg2, opt, num_microbatches=2)
    s_plain, m_plain = jax.jit(step)(state, batch)
    with mesh, active_rules(rules, mesh):
        ss = state_shardings(cfg2, mesh, rules)
        bs = batch_shardings(cfg2, cell, mesh, rules)
        s_shard, m_shard = jax.jit(
            step, in_shardings=(ss, bs), out_shardings=(ss, None))(
            state, batch)
    np.testing.assert_allclose(float(m_plain["loss"]),
                               float(m_shard["loss"]), atol=1e-4, rtol=1e-4)
    for a, b in zip(jax.tree.leaves(s_plain["params"]),
                    jax.tree.leaves(s_shard["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)
    print("OK sharded==plain train step")

    # ---- 3) forward equality under sharding for a hybrid arch ----------
    cfg3 = dataclasses.replace(
        tiny_config("recurrentgemma-9b"), dtype=jnp.float32)
    params3 = T.init_params(jax.random.PRNGKey(0), cfg3)
    toks = jax.random.randint(jax.random.PRNGKey(2), (8, 12), 0,
                              cfg3.vocab, jnp.int32)
    plain, _ = T.forward(params3, toks, cfg3)
    with mesh, active_rules(rules, mesh):
        shrd, _ = jax.jit(lambda p, t: T.forward(p, t, cfg3))(params3, toks)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(shrd),
                               atol=5e-4, rtol=5e-4)
    print("OK sharded==plain forward (hybrid)")
""")


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    for marker in ("OK a2a==sort_scatter", "OK sharded==plain train step",
                   "OK sharded==plain forward (hybrid)"):
        assert marker in r.stdout
