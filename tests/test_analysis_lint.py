"""Tests for the DES-invariant AST lint (:mod:`repro.analysis.lint`)."""

from repro.analysis.lint import lint_source, run_lint


def test_repo_is_lint_clean():
    assert run_lint() == []


# ----------------------------------------------------------------- ANA001
def test_ana001_flags_direct_bfs_calls_outside_layers():
    src = ("def f(fs):\n"
           "    fs.bfs_attach('/x', 1)\n"
           "    bfs_query('/x')\n"
           "    fs.bfs_query_file('/x')\n")
    v = lint_source(src, "benchmarks/foo.py")
    assert [x.rule for x in v] == ["ANA001"] * 3
    assert v[0].line == 2
    assert "consistency" in v[0].message


def test_ana001_allowed_in_the_layer_modules():
    src = "def f(fs):\n    fs.bfs_attach('/x', 1)\n"
    assert lint_source(src, "src/repro/core/consistency.py") == []
    assert lint_source(src, "src/repro/core/basefs.py") == []


# ----------------------------------------------------------------- ANA002
def test_ana002_missing_declarations():
    src = "class BadFS(_LayeredFS):\n    name = 'bad'\n"
    v = lint_source(src, "src/repro/core/consistency.py")
    assert {x.rule for x in v} == {"ANA002"}
    missing = {m.split("'")[1] for m in (x.message for x in v)}
    assert missing == {"sync_points", "consumer_edges", "sync_op_kinds"}


def test_ana002_sync_op_kind_without_method():
    src = ("class BadFS(_LayeredFS):\n"
           "    name = 'bad'\n"
           "    sync_points = ()\n"
           "    consumer_edges = ()\n"
           "    sync_op_kinds = {'commit': 'commit'}\n")
    v = lint_source(src, "src/repro/core/consistency.py")
    assert len(v) == 1 and v[0].rule == "ANA002"
    assert "commit" in v[0].message
    good = src + "    def commit(self, fh):\n        pass\n"
    assert lint_source(good, "src/repro/core/consistency.py") == []


def test_ana002_only_checked_in_consistency_module():
    src = "class OtherFS(_LayeredFS):\n    pass\n"
    assert lint_source(src, "src/repro/io/foo.py") == []


# ----------------------------------------------------------------- ANA003
def test_ana003_flags_hand_recorded_rpc():
    src = ("from repro.core.basefs import EventKind\n"
           "def f(ledger):\n"
           "    ledger.record(EventKind.RPC, 0, 1)\n")
    v = lint_source(src, "src/repro/io/foo.py")
    assert [x.rule for x in v] == ["ANA003"]
    assert lint_source(src, "src/repro/core/basefs.py") == []


def test_ana003_other_event_kinds_pass():
    src = ("from repro.core.basefs import EventKind\n"
           "def f(ledger):\n"
           "    ledger.record(EventKind.ATTACH, 0, 1)\n")
    assert lint_source(src, "src/repro/io/foo.py") == []


# ----------------------------------------------------------------- ANA004
def test_ana004_flags_hand_stamped_fault_metadata():
    src = ("from repro.core.basefs import EventKind\n"
           "def f(ledger):\n"
           "    ledger.record(EventKind.MEM_WRITE, 0, 1, retries=3)\n"
           "    ledger.record(EventKind.SSD_WRITE, 0, 1, failover=1)\n")
    v = lint_source(src, "src/repro/io/foo.py")
    assert [x.rule for x in v] == ["ANA004"] * 2
    assert "retries" in v[0].message and "failover" in v[1].message
    # The fault plane itself may stamp them.
    assert lint_source(src, "src/repro/core/basefs.py") == []
    assert lint_source(src, "src/repro/core/faults.py") == []


def test_ana004_covers_direct_event_construction():
    src = ("from repro.core.basefs import Event, EventKind\n"
           "def f():\n"
           "    return Event(EventKind.MEM_WRITE, 0, 1, failover=1)\n")
    v = lint_source(src, "benchmarks/foo.py")
    assert [x.rule for x in v] == ["ANA004"]


def test_ana004_faultless_calls_pass():
    src = ("from repro.core.basefs import EventKind\n"
           "def f(ledger):\n"
           "    ledger.record(EventKind.MEM_WRITE, 0, 1, peer=2)\n")
    assert lint_source(src, "src/repro/io/foo.py") == []


# ----------------------------------------------------------------- ANA005
def test_ana005_flags_direct_bulk_kernel_calls():
    src = ("def f(fs, batcher, prog):\n"
           "    fs.bulk_write_run({}, prog.client, prog.offset,\n"
           "                      prog.size, 0, 4, None)\n"
           "    fs.bulk_read_run({}, prog.client, prog.offset,\n"
           "                     prog.size, 0, 4)\n"
           "    batcher.submit_run('attach', 0, '/f', 0, [(1, 24)])\n")
    v = lint_source(src, "benchmarks/foo.py")
    assert [x.rule for x in v] == ["ANA005"] * 3
    assert "run_ops" in v[0].message
    # The layer API and BaseFS itself are the legal entry points.
    assert lint_source(src, "src/repro/core/consistency.py") == []
    assert lint_source(src, "src/repro/core/basefs.py") == []


def test_ana005_ignores_other_calls_and_tests():
    src = "def f(fs):\n    fs.run_ops(None, None)\n"
    assert lint_source(src, "src/repro/io/foo.py") == []
    # Tests are outside SCAN_DIRS: run_lint never visits them, so
    # hand-driving a kernel in a unit test stays legal.
    from repro.analysis.lint import SCAN_DIRS
    assert not any(d.startswith("tests") for d in SCAN_DIRS)


# ------------------------------------------------------------------- misc
def test_violation_formatting():
    v = lint_source("bfs_query('/f')\n", "examples/demo.py")[0]
    s = str(v)
    assert s.startswith("examples/demo.py:1: ANA001")
