"""BaseFS primitive semantics (paper Table 5) + consistency layers (Table 6)."""

import pytest

from repro.core.basefs import SEEK_END, SEEK_SET, BaseFS, BFSError, EventKind
from repro.core.consistency import (
    CommitFS,
    MPIIOFS,
    PosixFS,
    SessionFS,
    make_fs,
)


class TestBaseFSPrimitives:
    def test_write_read_own_buffer(self):
        fs = BaseFS()
        c = fs.client(0)
        h = fs.bfs_open(c, "/f")
        fs.bfs_write(c, h, b"hello world")
        fs.bfs_seek(c, h, 0, SEEK_SET)
        assert fs.bfs_read(c, h, 11, owner=0) == b"hello world"

    def test_write_not_visible_without_attach(self):
        fs = BaseFS()
        w, r = fs.client(0), fs.client(1)
        hw = fs.bfs_open(w, "/f")
        fs.bfs_write(w, hw, b"secret")
        hr = fs.bfs_open(r, "/f")
        # No attach: reader queries find nothing; PFS read returns zeros.
        assert fs.bfs_query(r, hr, 0, 6) == []
        assert fs.bfs_read(r, hr, 6, owner=None) == b"\0" * 6

    def test_attach_then_cross_client_read(self):
        fs = BaseFS()
        w, r = fs.client(0), fs.client(1)
        hw = fs.bfs_open(w, "/f")
        fs.bfs_write(w, hw, b"abcdef")
        fs.bfs_attach(w, hw, 0, 6)
        hr = fs.bfs_open(r, "/f")
        owners = fs.bfs_query(r, hr, 0, 6)
        assert len(owners) == 1 and owners[0].value == 0
        assert fs.bfs_read(r, hr, 6, owner=0) == b"abcdef"

    def test_attach_unwritten_is_error(self):
        fs = BaseFS()
        c = fs.client(0)
        h = fs.bfs_open(c, "/f")
        fs.bfs_write(c, h, b"ab")
        with pytest.raises(BFSError):
            fs.bfs_attach(c, h, 0, 10)  # covers unwritten bytes

    def test_attach_file_noop_when_clean(self):
        fs = BaseFS()
        c = fs.client(0)
        h = fs.bfs_open(c, "/f")
        rpc_before = fs.ledger.count(EventKind.RPC)
        assert fs.bfs_attach_file(c, h) == 0
        assert fs.ledger.count(EventKind.RPC) == rpc_before  # no-op: no RPC

    def test_attach_takeover_between_clients(self):
        fs = BaseFS()
        a, b = fs.client(0), fs.client(1)
        ha = fs.bfs_open(a, "/f")
        hb = fs.bfs_open(b, "/f")
        fs.bfs_write(a, ha, b"AAAA")
        fs.bfs_attach(a, ha, 0, 4)
        fs.bfs_write(b, hb, b"BB")
        fs.bfs_attach(b, hb, 0, 2)  # takes over [0,2)
        reader = fs.client(2)
        hr = fs.bfs_open(reader, "/f")
        owners = {(iv.start, iv.end): iv.value
                  for iv in fs.bfs_query(reader, hr, 0, 4)}
        assert owners == {(0, 2): 1, (2, 4): 0}

    def test_detach_then_flush_serves_from_pfs(self):
        fs = BaseFS()
        w = fs.client(0)
        h = fs.bfs_open(w, "/f")
        fs.bfs_write(w, h, b"data0123")
        fs.bfs_attach_file(w, h)
        fs.bfs_flush_file(w, h)
        fs.bfs_detach_file(w, h)
        r = fs.client(1)
        hr = fs.bfs_open(r, "/f")
        assert fs.bfs_query(r, hr, 0, 8) == []  # ownership relinquished
        assert fs.bfs_read(r, hr, 8, owner=None) == b"data0123"

    def test_detach_never_attached_is_error(self):
        fs = BaseFS()
        c = fs.client(0)
        h = fs.bfs_open(c, "/f")
        fs.bfs_write(c, h, b"xy")
        with pytest.raises(BFSError):
            fs.bfs_detach(c, h, 0, 2)

    def test_close_discards_buffered_data(self):
        fs = BaseFS()
        c = fs.client(0)
        h = fs.bfs_open(c, "/f")
        fs.bfs_write(c, h, b"gone")
        fs.bfs_close(c, h)
        h2 = fs.bfs_open(c, "/f")
        assert fs.bfs_read(c, h2, 4, owner=None) == b"\0" * 4

    def test_owner_serves_after_close(self):
        """Attached ranges stay readable after the owner closes (listener)."""
        fs = BaseFS()
        w = fs.client(0)
        h = fs.bfs_open(w, "/f")
        fs.bfs_write(w, h, b"persist!")
        fs.bfs_attach_file(w, h)
        fs.bfs_close(w, h)
        r = fs.client(1)
        hr = fs.bfs_open(r, "/f")
        assert fs.bfs_read(r, hr, 8, owner=0) == b"persist!"

    def test_seek_tell_stat(self):
        fs = BaseFS()
        c = fs.client(0)
        h = fs.bfs_open(c, "/f")
        fs.bfs_write(c, h, b"0123456789")
        assert fs.bfs_tell(c, h) == 10
        fs.bfs_seek(c, h, -4, SEEK_END)
        assert fs.bfs_tell(c, h) == 6
        assert fs.bfs_stat_size(c, h) == 10

    def test_zero_fill_unwritten_before_eof(self):
        fs = BaseFS()
        c = fs.client(0)
        h = fs.bfs_open(c, "/f")
        fs.bfs_seek(c, h, 4, SEEK_SET)
        fs.bfs_write(c, h, b"tail")
        fs.bfs_seek(c, h, 0, SEEK_SET)
        assert fs.bfs_read(c, h, 4, owner=None) == b"\0" * 4

    def test_rpc_ledger_counts(self):
        fs = BaseFS()
        c = fs.client(0)
        h = fs.bfs_open(c, "/f")
        fs.bfs_write(c, h, b"x" * 100)  # no RPC
        assert fs.ledger.count(EventKind.RPC) == 0
        fs.bfs_attach_file(c, h)
        assert fs.ledger.count(EventKind.RPC, "attach") == 1
        fs.bfs_query(c, h, 0, 10)
        assert fs.ledger.count(EventKind.RPC, "query") == 1
        assert fs.ledger.total_bytes(EventKind.SSD_WRITE) == 100


class TestPosixFS:
    def test_write_immediately_visible(self):
        """POSIX: every write attaches; every read queries."""
        pfs = PosixFS()
        w = pfs.open(0, "/f")
        r = pfs.open(1, "/f")
        pfs.write(w, b"posix!")
        pfs.seek(r, 0)
        assert pfs.read(r, 6) == b"posix!"

    def test_rpc_per_op(self):
        pfs = PosixFS()
        w = pfs.open(0, "/f")
        for _ in range(5):
            pfs.write(w, b"abcd")
        assert pfs.fs.ledger.count(EventKind.RPC, "attach") == 5
        r = pfs.open(1, "/f")
        for _ in range(3):
            pfs.read(r, 4)
        assert pfs.fs.ledger.count(EventKind.RPC, "query") == 3


class TestCommitFS:
    def test_visible_only_after_commit(self):
        cfs = CommitFS()
        w = cfs.open(0, "/f")
        r = cfs.open(1, "/f")
        cfs.write(w, b"commit")
        cfs.seek(r, 0)
        assert cfs.read(r, 6) == b"\0" * 6  # not yet visible
        cfs.commit(w)
        cfs.seek(r, 0)
        assert cfs.read(r, 6) == b"commit"

    def test_one_attach_many_writes(self):
        cfs = CommitFS()
        w = cfs.open(0, "/f")
        for _ in range(10):
            cfs.write(w, b"y" * 8)
        cfs.commit(w)
        assert cfs.fs.ledger.count(EventKind.RPC, "attach") == 1

    def test_query_per_read(self):
        cfs = CommitFS()
        w = cfs.open(0, "/f")
        cfs.write(w, b"z" * 64)
        cfs.commit(w)
        r = cfs.open(1, "/f")
        for _ in range(8):
            cfs.read(r, 8)
        assert cfs.fs.ledger.count(EventKind.RPC, "query") == 8

    def test_read_own_writes_before_commit(self):
        cfs = CommitFS()
        w = cfs.open(0, "/f")
        cfs.write(w, b"mine")
        cfs.seek(w, 0)
        assert cfs.read(w, 4) == b"mine"


class TestSessionFS:
    def test_close_to_open_visibility(self):
        sfs = SessionFS()
        w = sfs.open(0, "/f")
        sfs.session_open(w)
        sfs.write(w, b"session")
        r = sfs.open(1, "/f")
        sfs.session_open(r)  # opened BEFORE writer's close
        sfs.seek(r, 0)
        assert sfs.read(r, 7) == b"\0" * 7  # snapshot: not visible
        sfs.session_close(w)
        sfs.session_open(r)  # re-open AFTER close
        sfs.seek(r, 0)
        assert sfs.read(r, 7) == b"session"

    def test_single_query_per_session(self):
        sfs = SessionFS()
        w = sfs.open(0, "/f")
        sfs.write(w, b"q" * 80)
        sfs.session_close(w)
        r = sfs.open(1, "/f")
        sfs.session_open(r)
        for i in range(10):
            sfs.seek(r, i * 8)
            assert sfs.read(r, 8) == b"q" * 8
        assert sfs.fs.ledger.count(EventKind.RPC, "query") == 1

    def test_session_close_attaches_once(self):
        sfs = SessionFS()
        w = sfs.open(0, "/f")
        for _ in range(20):
            sfs.write(w, b"w" * 4)
        sfs.session_close(w)
        assert sfs.fs.ledger.count(EventKind.RPC, "attach") == 1


class TestMPIIOFS:
    def test_sync_barrier_sync(self):
        """The sync-barrier-sync construct makes writes visible (§2.3.3)."""
        mfs = MPIIOFS()
        w = mfs.file_open(0, "/f")
        r = mfs.file_open(1, "/f")
        mfs.write(w, b"mpiio!")
        mfs.seek(r, 0)
        assert mfs.read(r, 6) == b"\0" * 6  # before syncs
        mfs.file_sync(w)   # writer sync
        # (barrier happens at application level)
        mfs.file_sync(r)   # reader sync
        mfs.seek(r, 0)
        assert mfs.read(r, 6) == b"mpiio!"

    def test_close_open_pair(self):
        mfs = MPIIOFS()
        w = mfs.file_open(0, "/f")
        mfs.write(w, b"closed")
        mfs.file_close(w)
        r = mfs.file_open(1, "/f")
        mfs.seek(r, 0)
        assert mfs.read(r, 6) == b"closed"


class TestMakeFS:
    def test_factory(self):
        assert isinstance(make_fs("posix"), PosixFS)
        assert isinstance(make_fs("commit"), CommitFS)
        assert isinstance(make_fs("session"), SessionFS)
        assert isinstance(make_fs("mpiio"), MPIIOFS)
        with pytest.raises(ValueError):
            make_fs("eventual")

    def test_shared_basefs(self):
        fs = BaseFS()
        a = make_fs("commit", fs)
        b = make_fs("session", fs)
        assert a.fs is b.fs

    def test_multi_owner_strided_read(self):
        """A read spanning ranges attached by different clients."""
        cfs = CommitFS()
        for pid in range(4):
            fh = cfs.open(pid, "/f")
            cfs.seek(fh, pid * 4)
            cfs.write(fh, bytes([65 + pid]) * 4)
            cfs.commit(fh)
        r = cfs.open(9, "/f")
        cfs.seek(r, 0)
        assert cfs.read(r, 16) == b"AAAABBBBCCCCDDDD"
