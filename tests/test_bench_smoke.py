"""Benchmark-driver smoke tests (tier-1 coverage for benchmarks/*).

Runs one minimal point per figure module through the real
``benchmarks.run`` machinery (``--fast --only figX``) with the scale
grids monkeypatched down to a single point, so driver plumbing, CSV
artifacts and claim evaluation (PASS/SKIP — never FAIL) are exercised on
every tier-1 run without hand-run sweeps.  Marked ``slow``: deselect
with ``-m "not slow"``.
"""

import csv
import os

import pytest

import benchmarks.common
import benchmarks.fig3_write as fig3_write
import benchmarks.fig4_read as fig4_read
import benchmarks.fig5_scr as fig5_scr
import benchmarks.fig6_dl as fig6_dl
import benchmarks.fig7_shard as fig7_shard
from benchmarks import run as bench_run

pytestmark = pytest.mark.slow

#: Per-figure grid shrink: (module, attribute, minimal value).
SHRINK = {
    "fig3": [(fig3_write, "NODES", (2,))],
    "fig4": [(fig4_read, "NODES", (2,))],
    "fig5": [(fig5_scr, "NODES", (3,)), (fig5_scr, "PARTICLES", 240_000)],
    "fig6": [(fig6_dl, "HOSTS", (2,)), (fig6_dl, "STRONG_TOTAL", 32),
             (fig6_dl, "WEAK_PER_PROC", 4), (fig6_dl, "SAMPLE", 8 * 1024)],
    "fig7": [(fig7_shard, "FAST_NODES", (2,)), (fig7_shard, "SHARDS", (1, 2)),
             (fig7_shard, "LINGER_US", (0.0, 50.0, 1000.0))],
}


@pytest.mark.parametrize("fig", sorted(SHRINK))
def test_figure_module_through_run_machinery(fig, monkeypatch, capsys,
                                             tmp_path):
    # Smoke-grid CSVs go to a tmpdir, not over the real artifacts.
    monkeypatch.setattr(benchmarks.common, "ARTIFACT_DIR", str(tmp_path))
    for mod, attr, val in SHRINK[fig]:
        monkeypatch.setattr(mod, attr, val)
    csv_path = os.path.join(str(tmp_path), f"{fig}.csv")
    rc = bench_run.main(["--fast", "--only", fig, "--no-roofline"])
    out = capsys.readouterr().out
    # Under-resolved claims must SKIP, not FAIL, and the driver exits 0.
    assert rc == 0, out
    assert "[FAIL]" not in out, out
    # The CSV artifact is written with the union header over all rows.
    with open(csv_path, newline="") as f:
        rows = list(csv.DictReader(f))
    assert rows, f"{fig}.csv is empty"
    header = rows[0].keys()
    mod = SHRINK[fig][0][0]
    for row_dict in mod.run(fast=True):
        assert set(row_dict.keys()) <= set(header)


def test_unknown_figure_name_exits_2(capsys):
    rc = bench_run.main(["--only", "fig8"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "fig8" in err and "fig3" in err and "fig7" in err
