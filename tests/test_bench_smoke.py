"""Benchmark-driver smoke tests (tier-1 coverage for benchmarks/*).

Runs one minimal point per figure module through the real
``benchmarks.run`` machinery (``--fast --only figX``) with the scale
grids monkeypatched down to a single point, so driver plumbing, CSV
artifacts and claim evaluation (PASS/SKIP — never FAIL) are exercised on
every tier-1 run without hand-run sweeps.  Marked ``slow``: deselect
with ``-m "not slow"``.
"""

import csv
import os

import pytest

import benchmarks.common
import benchmarks.fig3_write as fig3_write
import benchmarks.fig4_read as fig4_read
import benchmarks.fig5_scr as fig5_scr
import benchmarks.fig6_dl as fig6_dl
import benchmarks.fig7_shard as fig7_shard
import benchmarks.fig8_hot as fig8_hot
import benchmarks.fig9_faults as fig9_faults
from benchmarks import run as bench_run

pytestmark = pytest.mark.slow

#: Per-figure grid shrink: (module, attribute, minimal value).
SHRINK = {
    "fig3": [(fig3_write, "NODES", (2,))],
    "fig4": [(fig4_read, "NODES", (2,))],
    "fig5": [(fig5_scr, "NODES", (3,)), (fig5_scr, "PARTICLES", 240_000)],
    "fig6": [(fig6_dl, "HOSTS", (2,)), (fig6_dl, "STRONG_TOTAL", 32),
             (fig6_dl, "WEAK_PER_PROC", 4), (fig6_dl, "SAMPLE", 8 * 1024)],
    "fig7": [(fig7_shard, "FAST_NODES", (2,)), (fig7_shard, "SHARDS", (1, 2)),
             (fig7_shard, "LINGER_US", (0.0, 50.0, 1000.0)),
             (fig7_shard, "ACK_WINDOWS", (0, 1, 16)),
             (fig7_shard, "ACK_DED_M", 20)],
    "fig8": [(fig8_hot, "FAST_NODES", (2,))],
    "fig9": [(fig9_faults, "FAST_NODES", 2), (fig9_faults, "PROCS", 4)],
}


@pytest.mark.parametrize("fig", sorted(SHRINK))
def test_figure_module_through_run_machinery(fig, monkeypatch, capsys,
                                             tmp_path):
    # Smoke-grid CSVs go to a tmpdir, not over the real artifacts.
    monkeypatch.setattr(benchmarks.common, "ARTIFACT_DIR", str(tmp_path))
    for mod, attr, val in SHRINK[fig]:
        monkeypatch.setattr(mod, attr, val)
    csv_path = os.path.join(str(tmp_path), f"{fig}.csv")
    rc = bench_run.main(["--fast", "--only", fig, "--no-roofline"])
    out = capsys.readouterr().out
    # Under-resolved claims must SKIP, not FAIL, and the driver exits 0.
    assert rc == 0, out
    assert "[FAIL]" not in out, out
    # The CSV artifact is written with the union header over all rows.
    with open(csv_path, newline="") as f:
        rows = list(csv.DictReader(f))
    assert rows, f"{fig}.csv is empty"
    header = rows[0].keys()
    mod = SHRINK[fig][0][0]
    for row_dict in mod.run(fast=True):
        assert set(row_dict.keys()) <= set(header)


def test_unknown_figure_name_exits_2(capsys):
    rc = bench_run.main(["--only", "fig99"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "fig99" in err and "fig3" in err and "fig8" in err


def test_fig7_ack_window_column_in_smoke_grid(monkeypatch):
    # The ack-window sweep rides in every fig7 grid (incl. smoke): the
    # dedicated-writer sweep carries one row per window, the saturated
    # scale carries the 0-vs-max null pair, and every row exposes the
    # DES wire-message count next to the ledger event count.
    for mod, attr, val in SHRINK["fig7"]:
        monkeypatch.setattr(mod, attr, val)
    rows = fig7_shard.run(fast=True)
    ded = [r for r in rows if r["workload"] == "CN-W-ded/posix"]
    assert [r["ack_window"] for r in ded] == list(fig7_shard.ACK_WINDOWS)
    sat = [r for r in rows if r["workload"] == "CN-W/posix"
           and r["ack_window"] != ""]
    assert sorted(r["ack_window"] for r in sat) \
        == [0, fig7_shard.ACK_WINDOWS[-1]]
    assert all("rpc_msgs" in r and r["rpc_msgs"] >= 1 for r in rows)
    # Fire-and-forget pays on the latency-bound dedicated writers even
    # at smoke scale (the config is grid-independent).
    by_ack = {r["ack_window"]: r["read_bw"] for r in ded}
    assert by_ack[fig7_shard.ACK_WINDOWS[-1]] \
        >= 1.5 * by_ack[0]


def test_fig8_seed_reproducible(monkeypatch):
    monkeypatch.setattr(fig8_hot, "FAST_NODES", (2,))
    a = fig8_hot.run(fast=True, seed=7)
    b = fig8_hot.run(fast=True, seed=7)
    assert a == b
    c = fig8_hot.run(fast=True, seed=8)
    assert [r["seed"] for r in c] == [8] * len(c)
    # A different seed deals different hot offsets: the grid shape is
    # identical but at least one MEASURED cell moves (compare with the
    # seed column stripped, which differs by construction).
    assert [list(r) for r in a] == [list(r) for r in c]
    strip = [{k: v for k, v in r.items() if k != "seed"} for r in a]
    stripc = [{k: v for k, v in r.items() if k != "seed"} for r in c]
    assert strip != stripc


def test_csv_header_union_is_deterministic(tmp_path, monkeypatch):
    import csv as _csv

    from benchmarks.common import csv_fieldnames, save_csv

    monkeypatch.setattr(benchmarks.common, "ARTIFACT_DIR", str(tmp_path))
    rows = [
        {"b": 1, "a": 2},
        {"b": 3, "zz": 4, "mm": 5},
        {"mm": 6, "aa": 7},
    ]
    # First-row keys keep their declaration order; the union of later
    # extras is SORTED — not first-seen — so the header cannot depend on
    # which grid point ran first.
    assert csv_fieldnames(rows) == ["b", "a", "aa", "mm", "zz"]
    assert csv_fieldnames(list(rows)) == csv_fieldnames(rows)
    path = save_csv("hdr", rows)
    with open(path, newline="") as f:
        got = list(_csv.reader(f))
    assert got[0] == ["b", "a", "aa", "mm", "zz"]
    assert got[1] == ["1", "2", "", "", ""]
