"""Litmus tests for the four consistency layers (paper Tables 4 & 6).

Each test is a small program whose outcome the model specification fixes;
these are the storage-world analogues of the memory-model litmus tables
in paper §2.
"""

import pytest

from repro.core.basefs import BaseFS
from repro.core.consistency import (CommitFS, MPIIOFS, PosixFS, SessionFS,
                                    make_fs)

F = "/f"


def test_posix_write_immediately_visible():
    fs = PosixFS()
    w = fs.open(1, F, node=0)
    fs.write(w, b"abcd")
    r = fs.open(2, F, node=1)
    assert fs.read(r, 4) == b"abcd"


def test_commit_write_invisible_until_commit():
    fs = CommitFS()
    w = fs.open(1, F, node=0)
    fs.write(w, b"abcd")
    r = fs.open(2, F, node=1)
    # Not committed: reader sees the (empty) PFS content, zero-filled.
    assert fs.read(r, 4) == b"\0\0\0\0"
    fs.commit(w)
    fs.seek(r, 0)
    assert fs.read(r, 4) == b"abcd"


def test_commit_scopes_whole_file_since_last_commit():
    fs = CommitFS()
    w = fs.open(1, F, node=0)
    fs.write(w, b"aa")
    fs.commit(w)
    fs.write(w, b"bb")        # not yet committed
    r = fs.open(2, F, node=1)
    assert fs.read(r, 4) == b"aa\0\0"
    fs.commit(w)
    fs.seek(r, 0)
    assert fs.read(r, 4) == b"aabb"


def test_session_close_to_open_required():
    fs = SessionFS()
    w = fs.open(1, F, node=0)
    fs.session_open(w)
    fs.write(w, b"abcd")
    r = fs.open(2, F, node=1)
    fs.session_open(r)        # session opened BEFORE writer closed
    fs.session_close(w)
    assert fs.read(r, 4) == b"\0\0\0\0"   # stale snapshot: close-to-open!
    r2 = fs.open(3, F, node=1)
    fs.session_open(r2)       # opened AFTER the close -> sees the write
    assert fs.read(r2, 4) == b"abcd"


def test_session_concurrent_republish_is_racy():
    """A write published DURING an open reader session is a storage race:
    the model leaves the read undefined (§4 — no close->open MSC between
    them), and the checker must flag it."""
    from repro.core.checker import TracedRun
    from repro.core.model import SESSION_MODEL

    run = TracedRun(SessionFS())
    w = run.open(1, F, node=0)
    run.write_at(1, w, 0, b"aaaa")
    run.session_close(1, w)
    run.barrier([1, 2])
    r = run.open(2, F, node=1)
    run.session_open(2, r)
    run.read_at(2, r, 0, 4)          # properly synchronized: sees aaaa
    run.write_at(1, w, 0, b"bbbb")   # republish, NOT ordered vs next read
    run.session_close(1, w)
    run.read_at(2, r, 0, 4)          # racy: no open after the close
    race_free, races, _ = run.verify_scnf(SESSION_MODEL)
    assert not race_free
    assert run.reads[0].actual == b"aaaa"
    assert run.reads[1].actual in (b"aaaa", b"bbbb")  # undefined, not junk
    # Refreshing the session re-synchronizes: a new open sees bbbb.
    run.session_open(2, r)
    run.layer.seek(r, 0)
    assert run.layer.read(r, 4) == b"bbbb"


def test_mpiio_sync_barrier_sync():
    fs = MPIIOFS()
    w = fs.file_open(1, F, node=0)
    r = fs.file_open(2, F, node=1)
    fs.write(w, b"abcd")
    assert fs.read(r, 4) == b"\0\0\0\0"   # no sync yet
    fs.file_sync(w)                       # writer sync
    fs.seek(r, 0)
    assert fs.read(r, 4) == b"\0\0\0\0"   # reader has not synced
    fs.file_sync(r)                       # reader sync (after barrier)
    fs.seek(r, 0)
    assert fs.read(r, 4) == b"abcd"


def test_latest_attach_wins_overlap():
    fs = PosixFS()
    a = fs.open(1, F, node=0)
    b = fs.open(2, F, node=1)
    fs.write(a, b"aaaa")
    fs.seek(b, 2)
    fs.write(b, b"BB")
    r = fs.open(3, F, node=2)
    assert fs.read(r, 4) == b"aaBB"


def test_reader_prefers_own_uncommitted_writes():
    fs = CommitFS()
    w = fs.open(1, F, node=0)
    fs.write(w, b"xyz")
    fs.seek(w, 0)
    assert fs.read(w, 3) == b"xyz"   # Table 5: local writes visible locally


def test_flush_then_detach_serves_from_pfs():
    base = BaseFS()
    fs = CommitFS(base)
    w = fs.open(1, F, node=0)
    fs.write(w, b"abcd")
    fs.commit(w)
    base.bfs_flush_file(w.client, w.bfs_handle)
    base.bfs_detach_file(w.client, w.bfs_handle)
    r = fs.open(2, F, node=1)
    assert fs.read(r, 4) == b"abcd"  # nobody owns it; PFS has the bytes


def test_detach_without_flush_loses_visibility():
    fs = CommitFS()
    w = fs.open(1, F, node=0)
    fs.write(w, b"abcd")
    fs.commit(w)
    fs.fs.bfs_detach_file(w.client, w.bfs_handle)
    r = fs.open(2, F, node=1)
    assert fs.read(r, 4) == b"\0\0\0\0"  # Table 5: discarded, not flushed


def test_stat_size_sees_attached_eof():
    fs = CommitFS()
    w = fs.open(1, F, node=0)
    fs.write(w, b"x" * 100)
    fs.commit(w)
    r = fs.open(2, F, node=1)
    assert fs.stat_size(r) == 100


def test_make_fs_registry():
    for name, cls in (("posix", PosixFS), ("commit", CommitFS),
                      ("session", SessionFS), ("mpiio", MPIIOFS)):
        assert isinstance(make_fs(name), cls)
    with pytest.raises(ValueError):
        make_fs("release")
