"""Golden-ledger regression: defaults reproduce the pre-send-queue output.

The trace below was captured from the repository BEFORE the send-queue
batcher landed (PR 1's edit-in-place batcher, with ``num_shards=1,
batch=0``).  With batching off the batcher is pass-through, so the
default deployment must reproduce this ledger *event for event* — same
kinds, clients, byte counts, RPC types, peers, seqs, range counts and
shards — across all four consistency layers plus stat/detach.
"""

from repro.core.basefs import BaseFS
from repro.core.consistency import make_fs

#: (kind.value, client, nbytes, rpc_type, peer, seq, rpc_ranges, shard)
GOLDEN = [
    ("ssd_write", 0, 64, "", -1, 0, 1, 0),
    ("rpc", 0, 24, "attach", -1, 1, 1, 0),
    ("ssd_write", 0, 64, "", -1, 2, 1, 0),
    ("rpc", 0, 24, "attach", -1, 3, 1, 0),
    ("ssd_write", 0, 64, "", -1, 4, 1, 0),
    ("rpc", 0, 24, "attach", -1, 5, 1, 0),
    ("rpc", 1, 24, "query", -1, 6, 1, 0),
    ("net", 1, 192, "ssd", 0, 7, 1, 0),
    ("ssd_write", 2, 100, "", -1, 8, 1, 0),
    ("rpc", 2, 24, "attach", -1, 9, 1, 0),
    ("rpc", 3, 24, "query", -1, 10, 1, 0),
    ("net", 3, 100, "ssd", 2, 11, 1, 0),
    ("rpc", 3, 16, "stat", -1, 12, 1, 0),
    ("marker", -1, 0, "p2", -1, 13, 1, 0),
    ("rpc", 4, 24, "query", -1, 14, 1, 0),
    ("ssd_write", 4, 50, "", -1, 15, 1, 0),
    ("rpc", 4, 24, "attach", -1, 16, 1, 0),
    ("rpc", 5, 24, "query", -1, 17, 1, 0),
    ("net", 5, 50, "ssd", 4, 18, 1, 0),
    ("rpc", 6, 24, "query", -1, 19, 1, 0),
    ("ssd_write", 6, 40, "", -1, 20, 1, 0),
    ("rpc", 6, 24, "attach", -1, 21, 1, 0),
    ("rpc", 6, 24, "query", -1, 22, 1, 0),
    ("rpc", 7, 24, "query", -1, 23, 1, 0),
    ("net", 7, 40, "ssd", 6, 24, 1, 0),
    ("rpc", 0, 24, "detach", -1, 25, 1, 0),
]


def _golden_run() -> BaseFS:
    fs = BaseFS()  # defaults: num_shards=1, batch=0
    posix = make_fs("posix", fs)
    commit = make_fs("commit", fs)
    session = make_fs("session", fs)
    mpiio = make_fs("mpiio", fs)

    w = posix.open(0, "/g/a", node=0)
    for j in range(3):
        posix.seek(w, j * 64)
        posix.write(w, bytes([j]) * 64)
    r = posix.open(1, "/g/a", node=1)
    posix.seek(r, 0)
    assert posix.read(r, 192) == b"\0" * 64 + b"\1" * 64 + b"\2" * 64

    cw = commit.open(2, "/g/b", node=1)
    commit.write(cw, b"c" * 100)
    commit.commit(cw)
    cr = commit.open(3, "/g/b", node=0)
    commit.seek(cr, 0)
    assert commit.read(cr, 100) == b"c" * 100
    assert commit.stat_size(cr) == 100

    fs.ledger.mark_phase("p2")
    sw = session.open(4, "/g/c", node=2)
    session.session_open(sw)
    session.write(sw, b"s" * 50)
    session.session_close(sw)
    sr = session.open(5, "/g/c", node=3)
    session.session_open(sr)
    session.seek(sr, 0)
    assert session.read(sr, 50) == b"s" * 50
    session.session_close(sr)

    mw = mpiio.file_open(6, "/g/d", node=2)
    mpiio.write(mw, b"m" * 40)
    mpiio.file_sync(mw)
    mr = mpiio.file_open(7, "/g/d", node=3)
    mpiio.seek(mr, 0)
    assert mpiio.read(mr, 40) == b"m" * 40
    fs.bfs_detach(fs.clients[0], 1, 0, 64)
    return fs


def test_default_deployment_matches_pre_sendqueue_ledger():
    fs = _golden_run()
    got = [
        (e.kind.value, e.client, e.nbytes, e.rpc_type, e.peer, e.seq,
         e.rpc_ranges, e.shard)
        for e in fs.ledger.events
    ]
    assert got == GOLDEN


def test_default_deployment_has_no_sendqueue_artifacts():
    fs = _golden_run()
    # With batch=0 no event ever went through a send queue: the new
    # Event fields must carry their pass-through defaults, so the DES
    # prices the ledger exactly as the pre-send-queue model did.
    assert all(
        e.rpc_calls == 1 and e.flush == "" and e.linger == 0.0
        for e in fs.ledger.events
    )
    # drain() on an idle deployment appends nothing.
    n = len(fs.ledger.events)
    fs.drain()
    assert len(fs.ledger.events) == n
