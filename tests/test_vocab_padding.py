"""Vocab padding (§Perf iter B3): tables padded to %512, semantics intact."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config, tiny_config
from repro.models import transformer as T


def test_padded_vocab_multiple_and_coverage():
    for name in ARCHS:
        cfg = get_config(name)
        Vp = cfg.vocab_padded
        assert Vp % 512 == 0 and Vp >= cfg.vocab, (name, Vp)
        # the padded dim now divides every mesh-axis combination we use
        for axes in (16, 256, 512):
            assert Vp % axes == 0, (name, Vp, axes)


def test_odd_vocabs_were_the_problem():
    # The three odd vocabularies that replicated O(B*T*V) logits.
    for name, v in (("granite-moe-1b-a400m", 49155),
                    ("whisper-small", 51865),
                    ("paligemma-3b", 257216)):
        cfg = get_config(name)
        assert cfg.vocab == v
        assert cfg.vocab % 16 != 0 or cfg.vocab % 512 != 0
        assert cfg.vocab_padded % 512 == 0


def test_padded_slots_masked_in_logits():
    cfg = dataclasses.replace(tiny_config("starcoder2-3b"),
                              dtype=jnp.float32)  # vocab 128 -> padded 512
    assert cfg.vocab_padded == 512
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab,
                              jnp.int32)
    logits, _ = T.forward(params, toks, cfg)
    assert logits.shape[-1] == cfg.vocab_padded
    pad = np.asarray(logits[..., cfg.vocab:])
    assert np.all(pad < -1e29), "padded slots must be -inf-masked"
    # argmax can never select a padded id
    am = np.asarray(jnp.argmax(logits, axis=-1))
    assert np.all(am < cfg.vocab)


def test_embedding_table_shapes_padded():
    cfg = dataclasses.replace(tiny_config("qwen3-32b"), dtype=jnp.float32)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    assert params["embed"]["table"].shape[0] == cfg.vocab_padded
    assert params["embed"]["head"].shape[-1] == cfg.vocab_padded


def test_params_total_reports_unpadded_spec():
    # The public parameter count keeps the architecture's nominal vocab.
    cfg = get_config("granite-moe-1b-a400m")
    n_spec = cfg.params_total()
    unpadded = dataclasses.replace(cfg, pad_vocab_to=1)
    assert n_spec == unpadded.params_total()
