"""Unit tests for logical-axis resolution and the policy rule tables.

These run on ONE device: resolution logic is pure (mesh axis sizes come
from a fake mesh built over a reshaped single-device array is impossible,
so we use the documented 8-device subprocess for mesh-bound checks and
test the pure parts here with a stub mesh object).
"""

from types import SimpleNamespace

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import resolve_spec, rules_for


def _mesh(shape, names):
    dev = np.empty(shape, dtype=object)
    return SimpleNamespace(axis_names=names, devices=dev)


MESH = _mesh((16, 16), ("data", "model"))
RULES_TP = rules_for("tp", multi_pod=False)
RULES_FSDP = rules_for("fsdp", multi_pod=False)


def test_divisibility_drop():
    # 24 heads do not divide the 16-way model axis -> axis dropped.
    spec = resolve_spec(P("fsdp", "model", None), RULES_TP, MESH,
                        shape=(3072, 24, 128))
    assert spec == P(None, None, None)
    # 32 heads divide -> kept.
    spec = resolve_spec(P("fsdp", "model", None), RULES_TP, MESH,
                        shape=(4096, 32, 128))
    assert spec == P(None, "model", None)


def test_axis_used_once_left_wins():
    spec = resolve_spec(P("batch", "seq", None), RULES_TP, MESH,
                        shape=(256, 4096, 1024))
    # batch -> data; seq -> model
    assert spec == P("data", "model", None)
    # fsdp-policy batch consumes BOTH axes; seq then resolves to nothing.
    spec = resolve_spec(P("batch", "seq", None), RULES_FSDP, MESH,
                        shape=(256, 4096, 1024))
    assert spec == P(("data", "model"), None, None)


def test_fsdp_batch_covers_both_axes_cumulatively():
    # 32 shards only 16-way (data); model would need 512 divisibility.
    spec = resolve_spec(P("batch", None, None), RULES_FSDP, MESH,
                        shape=(32, 4096, 1024))
    assert spec == P("data", None, None)
    spec = resolve_spec(P("batch", None, None), RULES_FSDP, MESH,
                        shape=(256, 4096, 1024))
    assert spec == P(("data", "model"), None, None)


def test_multi_pod_pod_axis_composes_with_data():
    mesh3 = _mesh((2, 16, 16), ("pod", "data", "model"))
    rules = rules_for("tp", multi_pod=True)
    # pod LAST: cumulative divisibility must claim data (and model, for
    # fsdp/dp policies) before the pod axis doubles the product.
    spec = resolve_spec(P("batch", None), rules, mesh3, shape=(256, 8))
    assert spec == P(("data", "pod"), None)
    rules_f = rules_for("fsdp", multi_pod=True)
    spec = resolve_spec(P("batch", None, None), rules_f, mesh3,
                        shape=(256, 4096, 1024))
    assert spec == P(("data", "model"), None, None)  # pod would need 512


def test_unknown_logical_name_passes_through_known_axis():
    spec = resolve_spec(P("model",), RULES_TP, MESH, shape=(64,))
    assert spec == P("model")


def test_policies_reject_unknown():
    with pytest.raises(ValueError):
        rules_for("pp", multi_pod=False)


def test_dp_policy_batch_uses_model_axis_too():
    rules = rules_for("dp", multi_pod=False)
    spec = resolve_spec(P("batch", None, None), rules, MESH,
                        shape=(256, 10, 10))
    assert spec == P(("data", "model"), None, None)
