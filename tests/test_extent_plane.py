"""Zero-copy extent data plane (PR 4).

* payload semantics: slicing, equality (symbolic AND content fallback),
  concat re-coalescing, the extent log and the PFS extent file;
* golden-ledger regression: seeded runs on the extent plane vs the
  retained byte-moving fallback (``BaseFS(materialize=True)``) produce
  event-for-event identical ledgers and identical DES times across all
  four consistency models;
* pattern_bytes memoization (satellite): template-cached expansion is
  byte-identical to the direct formula;
* incremental ledger counters (satellite): O(1) count/total_bytes agree
  with a full scan;
* fig8 hot-set satellite: the strided hot set drives the adaptive
  router through the override/move path.
"""

import pytest

from repro.core.basefs import BaseFS, EventKind
from repro.core.extents import (
    ByteSlab,
    Chain,
    ExtentFile,
    ExtentLog,
    PatternExtent,
    ZeroExtent,
    as_payload,
    concat,
)
from repro.core.costmodel import CostModel
from repro.io.workloads import (
    cc_r,
    ckpt_w,
    pattern_bytes,
    pattern_extent,
    rn_r_hot_set,
    run_workload,
)


def _gen(offset: int, size: int) -> bytes:
    return bytes((offset + i * 7) & 0xFF for i in range(size))


class TestPayloadSemantics:
    def test_byteslab_roundtrip_and_zero_copy_slice(self):
        raw = b"0123456789"
        p = ByteSlab(raw)
        assert len(p) == 10 and p.to_bytes() is raw
        s = p.slice(2, 5)
        assert s.to_bytes() == b"23456"
        assert s.data is raw  # a view, not a copy

    def test_pattern_extent_matches_generator(self):
        p = PatternExtent(_gen, 100, 64)
        assert p.to_bytes() == _gen(100, 64)

    def test_pattern_slice_is_window_not_readdress(self):
        # The generator is NOT shift-invariant: slicing must narrow the
        # window over the SAME gen(offset, size) call.
        p = PatternExtent(pattern_bytes, 4096, 256)
        assert p.slice(3, 50).to_bytes() == pattern_bytes(4096, 256)[3:53]

    def test_symbolic_equality_no_materialization(self):
        calls = []

        def gen(offset, size):
            calls.append((offset, size))
            return bytes(size)

        a = PatternExtent(gen, 0, 1 << 30)  # 1 GiB, never expanded
        b = PatternExtent(gen, 0, 1 << 30)
        assert a == b
        assert calls == []

    def test_content_fallback_equality(self):
        p = PatternExtent(_gen, 5, 32)
        assert p == _gen(5, 32)
        assert _gen(5, 32) == p  # reflected
        assert p != _gen(6, 32)
        assert ZeroExtent(4) == b"\0\0\0\0"
        assert ZeroExtent(4) != b"\0\0\0\1"
        assert ZeroExtent(4) != b"\0\0\0"  # length mismatch

    def test_concat_recoalesces_split_extent(self):
        p = PatternExtent(_gen, 9, 100)
        halves = [p.slice(0, 37), p.slice(37, 63)]
        merged = concat(halves)
        assert isinstance(merged, PatternExtent)
        assert merged == p  # symbolic again after the round trip

    def test_concat_heterogeneous_chain(self):
        c = concat([ByteSlab(b"ab"), ZeroExtent(3), ZeroExtent(2)])
        assert isinstance(c, Chain)
        assert c.to_bytes() == b"ab\0\0\0\0\0"
        assert c.slice(1, 4).to_bytes() == b"b\0\0\0"
        assert c[1:5] == b"b\0\0\0"
        assert c[0] == ord("a")

    def test_as_payload(self):
        assert isinstance(as_payload(b"xy"), ByteSlab)
        assert isinstance(as_payload(bytearray(b"xy")), ByteSlab)
        p = ZeroExtent(1)
        assert as_payload(p) is p
        with pytest.raises(TypeError):
            as_payload(123)

    def test_extent_log(self):
        log = ExtentLog()
        assert log.append(ByteSlab(b"abcd")) == 0
        assert log.append(PatternExtent(_gen, 0, 6)) == 4
        assert len(log) == 10
        assert log.read(0, 4) == b"abcd"
        assert log.read(2, 5).to_bytes() == b"cd" + _gen(0, 6)[:3]
        with pytest.raises(ValueError):
            log.read(8, 4)  # past end

    def test_extent_file_overwrite_and_zero_fill(self):
        f = ExtentFile()
        f.write(4, ByteSlab(b"AAAA"))
        f.write(6, ByteSlab(b"bb"))
        assert f.size == 8
        assert f.read(0, 10).to_bytes() == b"\0\0\0\0AAbb\0\0"
        # Overwrite in the middle splits the loser's payload window.
        f.write(5, ByteSlab(b"x"))
        assert f.read(4, 4).to_bytes() == b"Axbb"
        assert f.read(6, 6).to_bytes() == b"bb\0\0\0\0"  # past EOF zeros


class TestGoldenLedgerExtentVsByte:
    """Seeded runs: extent mode vs the byte-mode fallback are
    ledger-identical and DES-identical (the tentpole's safety net)."""

    MODELS = ("posix", "commit", "session", "mpiio")

    @staticmethod
    def _events(ledger):
        return [
            (e.kind.value, e.client, e.nbytes, e.rpc_type, e.peer, e.seq,
             e.rpc_ranges, e.shard, e.rpc_calls, e.flush, e.linger, e.deps,
             e.opened_after, e.last_after, e.forced_after)
            for e in ledger.events
        ]

    @pytest.mark.parametrize("model", MODELS)
    def test_ledgers_and_des_identical(self, model):
        cfg = cc_r(2, 8 * 1024, model, p=3, m=4)
        results = {}
        for materialize in (False, True):
            res = run_workload(cfg, materialize=materialize)
            results[materialize] = res
        ext, mat = results[False], results[True]
        assert [p.name for p in ext.phases] == [p.name for p in mat.phases]
        for pe, pm in zip(ext.phases, mat.phases):
            assert pe.duration == pm.duration  # identical DES times
            assert pe.bytes_by_kind == pm.bytes_by_kind
        assert ext.rpc_counts == mat.rpc_counts
        assert ext.verified_reads == mat.verified_reads

    @pytest.mark.parametrize("model", MODELS)
    def test_event_for_event(self, model):
        cfg = ckpt_w(2, 8 * 1024, model, p=2, m=3)
        ledgers = {}
        for materialize in (False, True):
            fs = BaseFS(batch=4, materialize=materialize)
            run_workload(cfg, fs=fs)
            ledgers[materialize] = self._events(fs.ledger)
        assert ledgers[False] == ledgers[True]

    def test_byte_mode_materializes_same_content(self):
        # The byte plane must store the SAME bytes the extent plane
        # describes: cross-check one full read in both modes.
        cfg = cc_r(2, 512, "commit", p=2, m=2)
        for materialize in (False, True):
            res = run_workload(cfg, materialize=materialize)
            assert res.verified_reads == cfg.readers * cfg.m_r


class TestPatternMemoization:
    def test_template_cache_matches_formula(self):
        for offset, size in ((0, 0), (0, 1), (8192, 64), (12345, 100),
                             (8 * 1024 * 1024, 300 * 1024)):
            head = (offset * 2654435761) & 0xFF
            body = bytes(((offset >> 3) + i) & 0xFF
                         for i in range(min(size, 64)))
            reps = size // len(body) + 1 if body else 0
            want = (bytes([head]) + body * reps)[:size] if size else b""
            assert pattern_bytes(offset, size) == want

    def test_cacheable_sizes_return_same_object(self):
        a = pattern_bytes(8192, 8192)
        b = pattern_bytes(8192, 8192)
        assert a is b  # memoized expansion

    def test_pattern_extent_wraps_pattern_bytes(self):
        p = pattern_extent(64, 128)
        assert p.gen is pattern_bytes
        assert p.to_bytes() == pattern_bytes(64, 128)


class TestLedgerCounters:
    def test_incremental_counters_match_scan(self):
        fs = BaseFS()
        c = fs.client(0)
        h = fs.bfs_open(c, "/f")
        fs.bfs_write(c, h, b"x" * 100)
        fs.bfs_attach_file(c, h)
        fs.bfs_query(c, h, 0, 50)
        fs.ledger.mark_phase("p")
        led = fs.ledger
        for kind in EventKind:
            assert led.count(kind) == sum(
                1 for e in led.events if e.kind is kind)
            assert led.total_bytes(kind) == sum(
                e.nbytes for e in led.events if e.kind is kind)
        assert led.count(EventKind.RPC, "attach") == 1
        assert led.count(EventKind.RPC, "query") == 1
        assert led.count(EventKind.RPC, "detach") == 0
        led.clear()
        assert led.count(EventKind.RPC) == 0
        assert led.total_bytes(EventKind.SSD_WRITE) == 0


class TestHotSetOverridePath:
    def test_strided_hot_set_engages_rebalancer_overrides(self):
        # 8 KB accesses shrink the adaptive stripe to 8 KiB; the hot
        # blocks sit 8 blocks apart, so every hot stripe index is
        # congruent mod 8 and the set collides on ONE shard — the
        # rebalancer must answer with explicit overrides + migrations.
        cfg = rn_r_hot_set(8, 8 * 1024, "commit", p=16, m=10, seed=0,
                           hot_frac=0.9, hot_blocks=16, hot_stride=8)
        fs = BaseFS(num_shards=8, adaptive=True)
        res = run_workload(cfg, fs=fs)
        router = fs.server.router
        assert router._overrides, "override/move path was not exercised"
        assert res.rpc_counts["migrate"] > 0
        # The overrides must actually SPREAD the hot stripes: every
        # override target must differ from the stripe's crc32 home shard
        # (an override back onto the colliding shard would be a no-op).
        import zlib
        for (path, idx), target in router._overrides.items():
            home = (zlib.crc32(path.encode()) + idx) % router.num_shards
            assert target != home

    def test_hot_stride_default_is_backward_compatible(self):
        from repro.io.workloads import rn_r_hot, _read_offsets
        cfg = rn_r_hot(4, 8 * 1024, "commit", p=4, m=8, seed=3)
        assert cfg.hot_stride == 1
        offs = _read_offsets(cfg, 0)
        hot = max(1, min(cfg.hot_blocks, cfg.writers * cfg.m_w))
        assert all(o % cfg.s == 0 for o in offs)
        assert any(o // cfg.s < hot for o in offs)


class TestDESReplayIdenticalAcrossPlanes:
    def test_phase_durations_bitwise_equal(self):
        cfg = cc_r(2, 8 * 1024, "session", p=2, m=3)
        durations = {}
        for materialize in (False, True):
            fs = BaseFS(materialize=materialize)
            run_workload(cfg, fs=fs)
            phases = CostModel().replay(fs.ledger)
            durations[materialize] = [(p.name, p.duration) for p in phases]
        assert durations[False] == durations[True]
