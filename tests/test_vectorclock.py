"""Golden + incremental-contract tests for the vector-clock hb engine.

The engine (:mod:`repro.analysis.vectorclock`) answers every
``Execution.hb`` query; ``Execution._build_hb`` stays as the O(n²)
reference oracle.  These tests pin the two equal on random executions
with interleaved construction/queries, and pin the incremental contract
through ``hb_stats()``: appends and forward so edges never trigger a
full rebuild, backward edges demote to topo mode, cycles raise.
"""

import random

import pytest

from repro.analysis.vectorclock import VectorClockIndex, _join
from repro.core.checker import TracedRun
from repro.core.consistency import PosixFS
from repro.core.model import Execution

F = "/vc"


def _closure(exe):
    """Reference hb predicate from the O(n²) reachability builder."""
    reach = exe._build_hb()
    return lambda a, b: b.op_id in reach[a.op_id]


def _random_exe(rng, n_pids=4, n_ops=36):
    exe = Execution()
    syncs = []
    for _ in range(n_ops):
        pid = rng.randrange(n_pids)
        roll = rng.random()
        if roll < 0.35:
            s = exe.sync(pid, "", "m")
            peers = [x for x in syncs if x.pid != pid]
            if peers and rng.random() < 0.7:
                exe.add_so(rng.choice(peers), s)
            syncs.append(s)
        elif roll < 0.70:
            off = rng.randrange(48)
            exe.write(pid, F, off, off + rng.randint(1, 12))
        else:
            off = rng.randrange(48)
            exe.read(pid, F, off, off + rng.randint(1, 12))
    return exe


def _assert_equiv(exe):
    ref = _closure(exe)
    for a in exe.ops:
        for b in exe.ops:
            if a is not b:
                assert exe.hb(a, b) == ref(a, b), (a.op_id, b.op_id)


def test_golden_equivalence_random():
    rng = random.Random(7)
    for _ in range(120):
        _assert_equiv(_random_exe(rng))


def test_append_only_is_single_pass():
    """The cache-invalidation fix: queries between appends cost O(new)."""
    exe = _random_exe(random.Random(3), n_ops=30)
    exe.hb(exe.ops[0], exe.ops[-1])
    stats = exe.hb_stats()
    assert stats["ops_indexed"] == len(exe.ops)
    assert stats["ops_processed"] == len(exe.ops)
    assert stats["full_builds"] == 0
    # Append five more ops + one forward edge at the frontier: only the
    # new ops get processed, no rebuild.
    s1 = exe.sync(0, "", "m")
    for i in range(3):
        exe.write(1, F, 8 * i, 8 * i + 4)
    s2 = exe.sync(1, "", "m")
    exe.add_so(s1, s2)
    exe.hb(s1, exe.ops[-1])
    stats = exe.hb_stats()
    assert stats["ops_processed"] == len(exe.ops)
    assert stats["full_builds"] == 0
    _assert_equiv(exe)


def test_interleaved_queries_and_edges_no_full_rebuild():
    rng = random.Random(13)
    for _ in range(25):
        exe = _random_exe(rng, n_ops=12)
        exe.hb(exe.ops[0], exe.ops[-1])
        # Grow the execution in bursts, querying after every burst; add
        # forward so edges both at the frontier and into the already-
        # indexed prefix (suffix re-derive, never a full rebuild).
        for _ in range(6):
            a = exe.sync(rng.randrange(4), "", "m")
            for _ in range(rng.randint(0, 3)):
                off = rng.randrange(48)
                exe.write(rng.randrange(4), F, off, off + 4)
            b = exe.sync((a.pid + 1) % 4, "", "m")
            exe.add_so(a, b)
            exe.hb(a, b)
        assert exe.hb_stats()["full_builds"] == 0
        _assert_equiv(exe)


def test_backward_edge_demotes_to_topo_mode():
    exe = Execution()
    a = exe.sync(0, "", "m")
    b = exe.sync(1, "", "m")
    exe.hb(a, b)
    assert exe.hb_stats()["full_builds"] == 0
    # b --so--> a points backward in creation order: still acyclic, but
    # the incremental pass can't handle it — a Kahn rebuild must run.
    exe.add_so(b, a)
    assert exe.hb(b, a)
    assert not exe.hb(a, b)
    assert exe.hb_stats()["full_builds"] >= 1
    _assert_equiv(exe)


def test_cycle_raises_like_the_closure_builder():
    exe = Execution()
    a = exe.sync(0, "", "m")
    b = exe.sync(1, "", "m")
    exe.add_so(a, b)
    exe.add_so(b, a)
    with pytest.raises(ValueError, match="cycle"):
        exe.hb(a, b)
    with pytest.raises(ValueError, match="cycle"):
        exe._build_hb()


def test_hub_barrier_leaves_share_one_snapshot():
    """O(P) barriers: every post-barrier snapshot aliases the hub's
    release dict instead of P copies of a P-entry vector."""
    run = TracedRun(PosixFS())
    pids = list(range(8))
    fhs = {p: run.open(p, F, node=p) for p in pids}
    for p in pids:
        run.write_at(p, fhs[p], 64 * p, bytes(8))
    exe = run.exe
    pre = exe.ops[:8]  # PosixFS open records no formal op; writes first
    leaves = run.barrier(pids)
    # Everything pre-barrier is hb everything post-barrier, cross-pid.
    post = [run.write_at(p, fhs[p], 64 * p + 16, bytes(4)) for p in pids]
    for p in pids:
        for q in pids:
            if p != q:
                assert exe.hb(pre[p], post[q])
    vc = exe._vc
    assert vc is not None
    assert len({id(vc.snapshot(lv)) for lv in leaves}) == 1


def test_join_aliases_dominating_input():
    small = {0: 1}
    big = {0: 5, 1: 2}
    assert _join([small, big]) is big
    merged = _join([{0: 5}, {1: 7}])
    assert merged == {0: 5, 1: 7}


def test_duck_typed_index_standalone():
    """The module is dependency-free: any op with op_id/pid/seq works."""

    class O:  # noqa: E742 - deliberate tiny stub
        def __init__(self, op_id, pid, seq):
            self.op_id, self.pid, self.seq = op_id, pid, seq

    ops = [O(0, 0, 0), O(1, 1, 0), O(2, 0, 1), O(3, 1, 1)]
    vc = VectorClockIndex(ops, [(0, 3)])
    assert vc.hb(ops[0], ops[3])
    assert not vc.hb(ops[1], ops[2])
    assert vc.hb(ops[0], ops[2])  # po
    # Live references: extend both lists, re-query without rebuilding.
    ops.append(O(4, 2, 0))
    vc.so_edges.append((3, 4))
    assert vc.hb(ops[0], ops[4])
    assert vc.stats()["full_builds"] == 0
