"""Tests for the seeded litmus fuzzer + ddmin minimizer.

The acceptance bar from the issue: 200 seeded programs across all four
layers, zero detector-vs-oracle disagreements.  Plus: generator
determinism, executor robustness on arbitrary ddmin slices, and ddmin
1-minimality on a known-racy program.
"""

import random

from repro.analysis.litmus import (
    FUZZ_MODELS, check_program, ddmin, format_program, fuzz, gen_program,
    run_litmus)
from repro.core.model import MODELS


def test_fuzz_acceptance_200_programs_zero_disagreements():
    res = fuzz(n=200, seed=0)
    assert res.ok, "\n".join(str(d) for d in res.disagreements)
    assert res.programs == 200
    assert res.runs == 200 * len(FUZZ_MODELS)
    # The generator must exercise BOTH detector outcomes.
    assert 0 < res.race_free_runs < res.runs
    assert "OK" in res.summary()


def test_gen_program_is_seed_deterministic():
    assert gen_program(random.Random(5)) == gen_program(random.Random(5))
    progs = [gen_program(random.Random(0)) for _ in range(3)]
    assert progs[0] == progs[1] == progs[2]


def test_run_litmus_robust_on_arbitrary_slices():
    """ddmin feeds run_litmus arbitrary subsequences: unmatched recvs,
    single-pid barriers, fences with no prior write must all be legal."""
    prog = [(0, ("recv", 3)), (0, ("barrier",)), (1, ("sync2",)),
            (0, ("sync1",)), (0, ("w", 0, 4)), (1, ("send", 9))]
    for model in FUZZ_MODELS:
        run_litmus(prog, model)
    for model in FUZZ_MODELS:
        for i in range(len(prog)):
            run_litmus(prog[:i] + prog[i + 1:], model)


def test_race_free_program_on_every_layer():
    """w -> fence1 -> barrier -> fence2 -> r is properly synchronized
    under each layer's own model, so check_program must report clean."""
    prog = [(0, ("w", 0, 8)), (0, ("sync1",)), (0, ("barrier",)),
            (1, ("sync2",)), (1, ("r", 0, 8))]
    for model in FUZZ_MODELS:
        failure, race_free = check_program(prog, model)
        assert failure is None, (model, failure)
        assert race_free, model


def test_ddmin_produces_one_minimal_racy_core():
    spec = MODELS["commit"]
    prog = [(0, ("w", 0, 8)), (0, ("sync1",)), (1, ("r", 0, 8)),
            (2, ("w", 4, 8)), (1, ("sync1",)), (0, ("barrier",))]

    def racy(p):
        return bool(run_litmus(p, "commit").storage_races(spec))

    assert racy(prog)
    small = ddmin(prog, racy)
    assert racy(small)
    assert len(small) <= 3
    # 1-minimality: dropping any single remaining step kills the race.
    for i in range(len(small)):
        cand = small[:i] + small[i + 1:]
        assert not cand or not racy(cand), format_program(cand)


def test_minimized_disagreement_recorded():
    """Force a 'disagreement' by checking a barrier-free racy program
    against a broken predicate path: fuzz with minimize=True must attach
    minimized programs to any finding.  With the real checker there are
    no findings — pin that the plumbing still returns cleanly."""
    res = fuzz(n=25, seed=1, minimize=True)
    assert res.ok
    for d in res.disagreements:  # pragma: no cover - empty on pass
        assert d.minimized is not None
