"""hlostats: while-loop multiplicity, collective wire model, HLO cost
parsing — validated on synthetic HLO text AND a real single-device lowering
whose analytic FLOPs are known exactly.
"""

import jax
import jax.numpy as jnp

from repro.launch import hlostats

SYNTH = """
HloModule m

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %ar = f32[8,8]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_multiplicity_from_while_trip_count():
    mult = hlostats.computation_multiplicity(SYNTH)
    assert mult.get("body") == 5
    assert mult.get("main") == 1


def test_collective_wire_model():
    stats = hlostats.parse_collectives(SYNTH, default_group=4)
    # all-reduce of 256B over group 4, ring wire = 2*(g-1)/g * payload,
    # executed 5 times by the while loop.
    expected = 256 * 2 * 3 / 4 * 5
    assert abs(stats.wire_bytes - expected) < 1e-6
    assert stats.count == 1 and stats.dynamic_count == 5


def test_wire_factors():
    assert hlostats._wire_factor("all-reduce", 4) == 2 * 3 / 4
    assert hlostats._wire_factor("reduce-scatter", 4) == 3.0
    assert hlostats._wire_factor("all-gather", 8) == 7 / 8
    assert hlostats._wire_factor("collective-permute", 2) == 1.0
    assert hlostats._wire_factor("all-reduce", 1) == 0.0


def test_real_lowering_flops_match_analytic():
    """scan of K matmuls: parsed unrolled dot-FLOPs == K * 2*M*N*Kdim."""
    M_, N_, K_ = 32, 48, 64
    trips = 7

    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), ()
        c, _ = jax.lax.scan(body, x, w)
        return c.sum()

    w = jnp.zeros((trips, K_, K_), jnp.float32)
    x = jnp.zeros((M_, K_), jnp.float32)
    text = jax.jit(f).lower(w, x).compile().as_text()
    hc = hlostats.parse_hlo_costs(text)
    analytic = trips * 2 * M_ * K_ * K_
    assert abs(hc["flops"] - analytic) / analytic < 0.01, hc
    # bytes: each trip must at least move the carry + weight slice
    per_trip_floor = (M_ * K_ + K_ * K_) * 4
    assert hc["bytes"] >= trips * per_trip_floor


def test_grad_lowering_flops_about_3x_forward():
    M_, K_ = 32, 64

    def f(w, x):
        return jnp.sum(jnp.tanh(x @ w) ** 2)

    w = jnp.zeros((K_, K_), jnp.float32)
    x = jnp.zeros((M_, K_), jnp.float32)
    fwd = hlostats.parse_hlo_costs(
        jax.jit(f).lower(w, x).compile().as_text())["flops"]
    bwd = hlostats.parse_hlo_costs(
        jax.jit(jax.grad(f)).lower(w, x).compile().as_text())["flops"]
    # grad wrt w only: recomputed fwd matmul + dw = 2x fwd (no dx needed).
    assert 1.8 <= bwd / fwd <= 3.8, (fwd, bwd)


def test_shape_bytes_tuple_and_scalar():
    assert hlostats._shape_bytes("f32[2,3]") == 24
    assert hlostats._shape_bytes("(f32[2,3], bf16[4])") == 24 + 8
    assert hlostats._shape_bytes("s32[]") == 4  # scalar = one element
